"""The task runtime: scheduling, pause/resume, dependency release.

Implements the runtime side of the paper's two APIs on a pool of worker
threads:

* **Pause/resume** (§4.1, §4.4): when a task blocks, the scheduling point is
  honoured by handing the core to another ready task.  Two strategies are
  provided:

  - ``block_mode="spare-thread"`` (default, what Nanos6 does): the blocked
    task's thread parks and, if that would leave fewer than ``num_workers``
    runnable threads, a *spare* worker thread is spawned.  This matches the
    paper's observation that the blocking mode creates "a number of threads
    (and stacks) proportional to the number of in-flight MPI operations"
    (§9) — an overhead the non-blocking mode avoids.

  - ``block_mode="nested"``: the blocked task's thread executes other ready
    tasks *nested on its own stack* until its context is unblocked.  No
    extra threads; used to demonstrate that §5's deadlock is resolved even
    with a single worker.

* **External events** (§4.3, §4.6): every task owns an
  :class:`~repro.core.events.EventCounter` initialised to 1; the implicit
  unit is decremented when the body finishes; dependency release happens at
  zero.  Tasks that bound external events therefore release their
  dependencies from the *polling service* thread that fulfils the last
  event — the runtime is fully re-entrant for that path.

* **Polling services** (§4.2, §4.5): a dedicated management thread serves
  callbacks every ``poll_interval`` seconds and idle workers serve them
  before going to sleep.

Additional production features beyond the paper:

* **Straggler mitigation**: tasks flagged ``idempotent=True`` are eligible
  for speculative re-execution when they exceed ``speculative_timeout``;
  the first completion wins.
* **Statistics** used by the benchmarks: threads spawned, block/unblock
  round-trips, tasks executed — the cost drivers behind the paper's
  blocking vs non-blocking comparison (Figs. 12–13).
"""

from __future__ import annotations

import collections
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..obs import registry as _reg
from ..obs import trace as _tr
from . import events as _ev
from .continuations import ContinuationEngine
from .events import BlockingContext, set_current_task, current_task
from .polling import PollingRegistry
from .taskgraph import (Task, TaskGraph, CREATED, READY, RUNNING, BLOCKED,
                        FINISHED, RELEASED)

NOTIFY_BACKENDS = ("polling", "continuation")


class TaskError(RuntimeError):
    """Raised by :meth:`TaskRuntime.taskwait` when a task body failed."""

    def __init__(self, task: Task, error: BaseException) -> None:
        super().__init__(f"task {task.name!r} (#{task.id}) failed: {error!r}")
        self.task = task
        self.error = error


class TaskRuntime:
    """A task-based runtime with data-flow dependencies (OmpSs-2 style)."""

    def __init__(self, num_workers: int = 4, *,
                 poll_interval: float = 0.001,
                 block_mode: str = "spare-thread",
                 max_threads: Optional[int] = None,
                 speculative_timeout: Optional[float] = None,
                 notify: Optional[str] = None) -> None:
        if block_mode not in ("spare-thread", "nested"):
            raise ValueError(f"unknown block_mode {block_mode!r}")
        if notify is None:
            # Continuation notification is the default (O(completions)
            # dispatches; ROADMAP carry-over after the CI soak); the env
            # override lets the whole tier-1 suite run under either
            # backend unchanged (CI exercises REPRO_NOTIFY=polling to
            # keep the legacy backend covered).
            notify = os.environ.get("REPRO_NOTIFY") or "continuation"
        if notify not in NOTIFY_BACKENDS:
            raise ValueError(f"unknown notify backend {notify!r}; "
                             f"one of {NOTIFY_BACKENDS}")
        self.num_workers = num_workers
        self.block_mode = block_mode
        self.notify = notify
        self.poll_interval = poll_interval
        self.max_threads = max_threads or num_workers + 512
        self.speculative_timeout = speculative_timeout

        self.graph = TaskGraph()
        self.polling = PollingRegistry(interval=poll_interval)
        self.stats: Dict[str, int] = collections.defaultdict(int)

        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        self._ready: collections.deque[Task] = collections.deque()
        self._threads: List[threading.Thread] = []
        self._live_threads = 0
        self._blocked_threads = 0
        self._unreleased = 0
        self._errors: List[TaskError] = []
        self._shutdown = False
        self._started = False
        self._continuations: Optional[ContinuationEngine] = None
        self._registered_services: List[Tuple[str, Callable, Any]] = []

    # -- polling-service bookkeeping ---------------------------------------
    def _register_service(self, name: str, fn: Callable,
                          data: Any = None) -> None:
        """Register a polling service AND remember it, so :meth:`close`
        can unregister deterministically — a failed collective or a
        restarted runtime must not leave services behind (asserted by
        the tier-1 stress tests)."""
        with self._lock:
            self._registered_services.append((name, fn, data))
        self.polling.register_polling_service(name, fn, data)

    @property
    def continuations(self) -> ContinuationEngine:
        """The runtime's completion-notification engine (lazy).

        One engine — and ONE registered polling service — per runtime:
        the ONLY completion dispatcher behind
        :func:`repro.core.tac.wait`/``iwait``/``iwaitall`` and the
        collectives :class:`~repro.core.collectives.ProgressEngine`
        (the legacy TAC ticket pool was folded into it).  Under
        ``notify="continuation"`` push-capable handles notify at match
        time; under ``notify="polling"`` the SAME engine runs in its
        compatibility mode (``push=False``): every handle rides the
        fallback poll list and is re-tested per tick, preserving the
        paper's §4.2 polling discipline.  Ready callbacks are dispatched
        by the dedicated poller, by idle workers (§4.5), and at the
        scheduling points (``submit``/``taskwait``) which drain the
        bounded completion queue.
        """
        eng = self._continuations
        if eng is None:
            with self._lock:
                eng = self._continuations
                if eng is None:
                    eng = ContinuationEngine(
                        push=(self.notify == "continuation"))
                    self._register_service("continuation engine",
                                           eng.service)
                    self._continuations = eng
        return eng

    def _drain_continuations(self) -> None:
        eng = self._continuations
        if eng is not None:
            eng.dispatch()

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        with self._cv:
            if self._started:
                return
            self._started = True
            for _ in range(self.num_workers):
                self._spawn_worker_locked()
        self.polling.start()
        if self.speculative_timeout is not None:
            self._register_service("straggler-watch",
                                   self._straggler_service)

    def close(self) -> None:
        with self._cv:
            self._shutdown = True
            self._cv.notify_all()
        for t in list(self._threads):
            t.join(timeout=5.0)
        # Deterministic teardown: every service this runtime registered
        # (collective progress engine, continuation engine, straggler
        # watch) is unregistered — including after failed machines — so
        # nothing stays registered forever.
        with self._lock:
            services, self._registered_services = \
                self._registered_services, []
        for name, fn, data in services:
            self.polling.unregister_polling_service(name, fn, data)
        self._drain_continuations()   # callbacks queued after last poll
        self.polling.stop()

    def __enter__(self) -> "TaskRuntime":
        self.start()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        try:
            if exc_type is None:
                self.taskwait()
        finally:
            self.close()

    # -- submission --------------------------------------------------------
    def submit(self, fn: Callable[..., Any], *args: Any,
               in_: Sequence[Any] = (), out: Sequence[Any] = (),
               inout: Sequence[Any] = (), name: Optional[str] = None,
               cost: float = 1.0, idempotent: bool = False,
               label: Optional[str] = None, rank: Optional[int] = None,
               **kwargs: Any) -> Task:
        """Create and submit a task.  Dependencies follow submission order.

        ``rank`` optionally attributes the task to a logical rank for
        trace/straggler accounting (:mod:`repro.obs`); it does not affect
        scheduling.
        """
        if not self._started:
            self.start()
        task = Task(fn, args, kwargs, name=name, runtime=self, cost=cost,
                    idempotent=idempotent, label=label, rank=rank)
        with self._cv:
            self._unreleased += 1
        if _tr.TRACING:
            _tr.TRACER.instant("task", "submit", rank=task.rank,
                               task=task.name)
        ready = self.graph.register(task, in_, out, inout)
        if ready:
            self._enqueue(task)
        # Task creation is a scheduling point (§4.4): serve any ready
        # continuation callbacks opportunistically on this thread.
        self._drain_continuations()
        return task

    # alias mirroring `#pragma oss task`
    task = submit

    def taskwait(self, handles: Sequence[Any] = ()) -> None:
        """Block until every submitted task has *released* its dependencies.

        Like ``#pragma oss taskwait`` this also waits for external events —
        a communication task only counts once its bound operations finished.
        ``handles`` optionally names extra in-flight operations to wait
        for as well: anything :func:`repro.core.tac.as_handle` accepts
        (the same :class:`~repro.core.tac.AsyncHandle` protocol the
        ``tac.wait`` family consumes), each waited with its OS-level
        ``wait()`` after the task graph drained.
        """
        if current_task() is not None:
            raise RuntimeError("taskwait() from inside a task is not "
                               "supported; use dependencies instead")
        while True:
            with self._cv:
                if self._unreleased <= 0:
                    break
                self._cv.wait(timeout=0.05)
            # taskwait is a scheduling point: drain ready continuations
            # so completion never waits on the dedicated poller alone.
            self._drain_continuations()
        for h in handles:
            # local import: tac imports this module at load time.
            from . import tac as _tac
            _tac.as_handle(h).wait()
        self._raise_errors()

    def _raise_errors(self) -> None:
        with self._cv:
            errors, self._errors = self._errors, []
        if errors:
            raise errors[0]

    # -- scheduling internals ------------------------------------------------
    def _enqueue(self, task: Task, *, front: bool = False) -> None:
        with self._cv:
            if task._state == CREATED:
                task._state = READY
            if front:
                self._ready.appendleft(task)
            else:
                self._ready.append(task)
            if _tr.TRACING:
                _reg.REGISTRY.gauge("runtime.ready_queue").set(
                    len(self._ready))
            self._cv.notify()

    def _spawn_worker_locked(self) -> None:
        t = threading.Thread(target=self._worker_loop,
                             name=f"repro-worker-{len(self._threads)}",
                             daemon=True)
        self._threads.append(t)
        self._live_threads += 1
        self.stats["threads_spawned"] += 1
        t.start()

    def _worker_loop(self) -> None:
        while True:
            task = self._next_task()
            if task is None:
                return
            self._run_task(task)

    def _next_task(self) -> Optional[Task]:
        """Pop a ready task; poll opportunistically while idle (§4.5)."""
        while True:
            with self._cv:
                if self._ready:
                    return self._ready.popleft()
                if self._shutdown:
                    self._live_threads -= 1
                    return None
                # Retire surplus spare threads once they go idle.
                if (self._live_threads - self._blocked_threads
                        > self.num_workers):
                    self._live_threads -= 1
                    self.stats["threads_retired"] += 1
                    return None
                self._cv.wait(timeout=self.poll_interval)
                if self._ready or self._shutdown:
                    continue
            # Nothing to run: serve the polling services before idling.
            self.polling.poll_once()

    def _run_task(self, task: Task) -> None:
        with task._state_lock:
            if task._completed_once:   # speculative duplicate lost the race
                return
            if task._state in (READY, CREATED):
                task._state = RUNNING
        task._started_at = time.monotonic()
        prev = current_task()
        set_current_task(task)
        try:
            result = task.fn(*task.args, **task.kwargs)
            error: Optional[BaseException] = None
        except BaseException as e:  # noqa: BLE001 - reported via taskwait
            result, error = None, e
        finally:
            set_current_task(prev)
        task._finished_at = time.monotonic()
        if _tr.TRACING:
            # One span per body execution: pause spans (the §4.1 wait)
            # nest inside it on the timeline.
            _tr.TRACER.span("task", "run", task._started_at,
                            task._finished_at, rank=task.rank,
                            task=task.name, label=task.label)

        with task._state_lock:
            if task._completed_once:
                return  # another speculative copy already completed
            task._completed_once = True
            task._state = FINISHED
        with self._cv:
            self.stats["tasks_executed"] += 1
        if error is not None:
            task.error = error
            with self._cv:
                self._errors.append(TaskError(task, error))
            # Fail-safe: force the release so dependents/taskwait do not hang
            # on events that will never be fulfilled.
            task._event_counter._force_release_on_error()
        else:
            task.result = result
            # Decrease the implicit unit bound at creation (§4.6).  If the
            # task bound external events this will NOT release yet.
            task._event_counter._decrease(1)

    # -- dependency release (called by EventCounter at zero) ---------------
    def _release_task(self, task: Task) -> None:
        task._state = RELEASED
        if _tr.TRACING:
            _tr.TRACER.instant("task", "release", rank=task.rank,
                               task=task.name)
        for succ in self.graph.on_release(task):
            self._enqueue(succ)
        with self._cv:
            self._unreleased -= 1
            self._cv.notify_all()

    # -- pause/resume hooks (called by events.block_current_task) ----------
    def _block_task(self, ctx: BlockingContext) -> None:
        task = ctx._task
        if self.block_mode == "nested":
            self._block_nested(ctx)
            return
        t_pause = time.monotonic() if _tr.TRACING else 0.0
        with self._cv:
            task._state = BLOCKED
            self._blocked_threads += 1
            self.stats["task_blocks"] += 1
            available = self._live_threads - self._blocked_threads
            if (available < self.num_workers
                    and self._live_threads < self.max_threads):
                # Keep the cores fed: thread-per-blocked-task (Nanos6-style).
                self._spawn_worker_locked()
        ctx._event.wait()
        with self._cv:
            self._blocked_threads -= 1
            task._state = RUNNING
            self.stats["task_resumes"] += 1
        if _tr.TRACING:
            _tr.TRACER.span("task", "pause", t_pause, time.monotonic(),
                            rank=task.rank, task=task.name,
                            mode="spare-thread")

    def _block_nested(self, ctx: BlockingContext) -> None:
        """Help-first blocking: run other ready tasks on this stack (§5)."""
        task = ctx._task
        task._state = BLOCKED
        t_pause = time.monotonic() if _tr.TRACING else 0.0
        with self._cv:
            self.stats["task_blocks"] += 1
        while not ctx._event.is_set():
            nested: Optional[Task] = None
            with self._cv:
                if self._ready:
                    nested = self._ready.popleft()
            if nested is not None:
                self._run_task(nested)
            else:
                self.polling.poll_once()
                ctx._event.wait(timeout=self.poll_interval)
        task._state = RUNNING
        with self._cv:
            self.stats["task_resumes"] += 1
        if _tr.TRACING:
            _tr.TRACER.span("task", "pause", t_pause, time.monotonic(),
                            rank=task.rank, task=task.name, mode="nested")

    def _on_task_unblock(self, task: Task) -> None:
        with self._cv:
            self.stats["task_unblocks"] += 1
            self._cv.notify_all()

    # -- straggler mitigation ----------------------------------------------
    def _straggler_service(self, _data: Any) -> bool:
        now = time.monotonic()
        for t in self.graph.tasks:
            if (t._state == RUNNING and t.idempotent
                    and not getattr(t, "_speculated", False)
                    and t._started_at is not None
                    and now - t._started_at > self.speculative_timeout):
                t._speculated = True
                with self._cv:
                    self.stats["speculative_reruns"] += 1
                if _tr.TRACING:
                    # The speculation decision, trace-visible: this task
                    # exceeded the timeout and gets re-enqueued; compare
                    # against analysis.straggler_scores on the same trace.
                    _tr.TRACER.instant(
                        "task", "speculate", rank=t.rank, task=t.name,
                        elapsed_s=now - t._started_at,
                        timeout_s=self.speculative_timeout)
                self._enqueue(t, front=True)
        return False  # keep the watchdog registered


# ---------------------------------------------------------------------------
# EventCounter needs a forced-release path for failed tasks; attach it here to
# keep events.py free of executor knowledge.
# ---------------------------------------------------------------------------
def _force_release_on_error(self) -> None:
    with self._lock:
        if self._released:
            return
        self._released = True
        self._count = 0
    self._runtime._release_task(self._task)


_ev.EventCounter._force_release_on_error = _force_release_on_error  # type: ignore[attr-defined]
