"""Schedule IR — collective communication schedules as *data* (tentpole).

The paper's central claim is that communication should be ordered by data
dependencies alone, not by which execution substrate runs it.  Before this
module the repository had the same schedules twice: once as Python
generators only the host progress engine could execute
(``repro.core.collectives``), and once hand-written as ``ppermute``/``psum``
calls only XLA could execute (``repro.core.overlap``).  Follow-on work (MPI
Continuations, arXiv:2112.11978; "MPI Progress For All", arXiv:2405.13807)
argues that decoupling the schedule *description* from progress/execution
is what makes such libraries portable across runtimes.

This module is that description.  Every algorithm — ring, recursive
doubling, Bruck, binomial tree, chain, pairwise, dissemination,
neighbourhood — is built **once** as a :class:`Schedule`: a DAG of
:class:`Send`/:class:`Recv`/:class:`Combine`/:class:`Slice`/... ops over
abstract communicator-local ranks, with a per-op payload *fraction* so a
single schedule serves every payload size.  Two consumers execute the same
IR:

* **Level A** — the host progress engine
  (:func:`repro.core.collectives._interpret`): walks a rank's program,
  posting ``isend``/``irecv`` through any communicator and yielding the
  handles it must wait on — blocking and event-bound modes, tag
  discipline, and sub-communicator rank translation all unchanged.
* **Level B** — the XLA lowering (:mod:`repro.core.lowering`): maps the
  same schedule to in-graph collectives (``ppermute`` rounds inside
  ``shard_map``, or a single fused node).

On top of the IR:

* **Segmented/pipelined schedules** (``segments=S``): payloads are chunked
  into ``S`` segments whose rounds interleave, so the *combine* of segment
  ``k`` overlaps the *transport* of segment ``k+1`` — the classic
  large-payload pipelining trick.  ``S=1`` reproduces the unsegmented
  schedules bit-for-bit.
* **An α-β(-γ) cost model** (:meth:`Schedule.cost`): per-transfer latency
  ``α``, per-byte wire time ``β``, and optionally per-byte combine time
  ``γ``, evaluated over the DAG under a one-port model (a rank's sends
  serialise; its combines serialise on its CPU; transport and combine of
  independent ops overlap).  ``cost(α, β, size)`` replaces bare round
  counts for algorithm *and* segment-count selection
  (:func:`best_schedule`), and feeds the simulator's
  predicted-vs-measured makespans
  (:func:`repro.core.simulate.schedule_tasks`).

The IR is deliberately tiny and serialisable: ops are frozen dataclasses
over primitive values, programs are tuples — a schedule can be printed,
diffed, cached, validated (:meth:`Schedule.validate`) and costed without
any runtime present.
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Op", "Send", "Recv", "Combine", "Copy", "Pack", "Unpack", "Slice",
    "Concat", "Const", "Schedule", "Transfer", "build", "build_neighbor",
    "build_hierarchical", "best_schedule", "load_calibration",
    "COLLECTIVES", "ALGORITHMS",
]

COLLECTIVES = ("barrier", "bcast", "reduce", "allreduce", "allgather",
               "reduce_scatter", "alltoall")
ALGORITHMS = ("ring", "doubling")


# ---------------------------------------------------------------------------
# Ops.  Frozen dataclasses over primitives: a schedule is pure data.
# Buffer names are hashables (strings or tuples); ``frac`` is the op's
# payload in units of the collective's nominal per-rank size ``m`` (so one
# schedule serves every payload size in the cost model).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Op:
    @property
    def reads(self) -> Tuple[Any, ...]:
        return ()

    @property
    def writes(self) -> Tuple[Any, ...]:
        return ()


@dataclass(frozen=True)
class Send(Op):
    peer: int          # destination rank
    buf: Any           # buffer holding the payload
    tag: Any           # schedule-unique transfer id (matches one Recv)
    frac: float = 1.0

    @property
    def reads(self):
        return (self.buf,)


@dataclass(frozen=True)
class Recv(Op):
    peer: int          # source rank
    buf: Any           # buffer the payload lands in
    tag: Any
    frac: float = 1.0

    @property
    def writes(self):
        return (self.buf,)


@dataclass(frozen=True)
class Combine(Op):
    """``out = op(a, b)`` — the collective's combining operator.

    Operand order is part of the schedule: every rank applies the operator
    with matching order, which is what makes IEEE results bitwise
    identical across ranks.
    """
    out: Any
    a: Any
    b: Any
    frac: float = 1.0

    @property
    def reads(self):
        return (self.a, self.b)

    @property
    def writes(self):
        return (self.out,)


@dataclass(frozen=True)
class Copy(Op):
    out: Any
    src: Any

    @property
    def reads(self):
        return (self.src,)

    @property
    def writes(self):
        return (self.out,)


@dataclass(frozen=True)
class Pack(Op):
    """``out = tuple(parts)`` — one wire message from several buffers
    (Bruck's log-round gathers ship growing item sets)."""
    out: Any
    parts: Tuple[Any, ...]

    @property
    def reads(self):
        return tuple(self.parts)

    @property
    def writes(self):
        return (self.out,)


@dataclass(frozen=True)
class Unpack(Op):
    """``outs... = src`` — split a packed message back into buffers."""
    outs: Tuple[Any, ...]
    src: Any

    @property
    def reads(self):
        return (self.src,)

    @property
    def writes(self):
        return tuple(self.outs)


@dataclass(frozen=True)
class Slice(Op):
    """``out = array_split(flatten(src), parts)[index]`` — the
    reduce-scatter output selection."""
    out: Any
    src: Any
    parts: int
    index: int

    @property
    def reads(self):
        return (self.src,)

    @property
    def writes(self):
        return (self.out,)


@dataclass(frozen=True)
class Concat(Op):
    """``out = concatenate(flatten(p) for p in parts)`` — reassemble a
    segmented payload, the inverse of per-segment ``Slice``/chunk
    splitting.  With ``like`` set the flat result is reshaped to that
    buffer's shape (segmented allgather returns each contribution in the
    sender's shape; the MPI uniform-count contract makes the local
    ``"in"`` a valid template)."""
    out: Any
    parts: Tuple[Any, ...]
    like: Any = None

    @property
    def reads(self):
        parts = tuple(self.parts)
        return parts if self.like is None else parts + (self.like,)

    @property
    def writes(self):
        return (self.out,)


@dataclass(frozen=True)
class Const(Op):
    """``out = value`` — schedule-immanent payloads (barrier tokens)."""
    out: Any
    value: Any

    @property
    def writes(self):
        return (self.out,)


@dataclass(frozen=True)
class Transfer:
    """One matched Send/Recv pair (the schedule's DAG edges)."""
    src: int
    dst: int
    tag: Any
    frac: float
    src_buf: Any
    dst_buf: Any


# ---------------------------------------------------------------------------
# Schedule
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Schedule:
    """A collective schedule: per-rank op programs over abstract ranks.

    ``input_kind`` tells the executor how a rank's operand binds to the
    initial buffers; ``output_kind`` how the final buffers form the rank's
    result:

    ======================  ====================================================
    input_kind              binding
    ======================  ====================================================
    ``none``                no operand (barrier)
    ``value``               ``env["in"] = value`` (raw object: bcast, allgather)
    ``array``               ``env["in"] = asarray(value)`` (reductions)
    ``chunks``              flattened value split into ``n×segments`` chunk
                            buffers ``("c", i[, s])`` (ring reductions)
    ``blocks``              ``env[("b", d)] = blocks[d]`` (alltoall)
    ``dirs``                ``env[("s", d)] = sends[d]`` (neighbourhood)
    ======================  ====================================================

    ======================  ====================================================
    output_kind             result
    ======================  ====================================================
    ``none``                ``None`` (barrier)
    ``buf``                 ``env[out_bufs[rank]]`` (``None`` slot → ``None``)
    ``concat``              chunks concatenated and reshaped (ring allreduce)
    ``list``                ``[env[("g", i)] for i in range(n)]``
    ``dirs``                ``{d: env[("rv", d)] for d in (in_dirs or
                            out_dirs)[rank]}``
    ======================  ====================================================
    """
    name: str
    algorithm: str
    n: int
    programs: Tuple[Tuple[Op, ...], ...]
    input_kind: str
    output_kind: str
    segments: int = 1
    out_bufs: Tuple[Any, ...] = ()
    out_dirs: Tuple[Tuple[Any, ...], ...] = ()
    # Receive directions per rank for asymmetric (directed) neighbourhood
    # schedules.  Empty means receives mirror sends (the symmetric case:
    # every out direction has a reciprocal in direction), which is every
    # schedule built before directed dist-graphs existed.
    in_dirs: Tuple[Tuple[Any, ...], ...] = ()
    chunk_bufs: Tuple[Any, ...] = ()
    # ``chunks`` inputs split into this many outer chunks (0 -> ``n``, the
    # flat-ring convention).  Hierarchical schedules split into the INTRA
    # group size instead: every rank of one pod owns one chunk.
    n_chunks: int = 0
    # Mesh factorisation metadata for multi-axis schedules, major -> minor:
    # ``(("inter", n_e), ("intra", n_i))`` with global rank
    # ``r = pod * n_i + local``.  Empty for flat single-axis schedules.
    axes: Tuple[Tuple[str, int], ...] = ()

    # -- structure ----------------------------------------------------------
    def transfers(self) -> List[Transfer]:
        sends: Dict[Any, Tuple[int, Send]] = {}
        recvs: Dict[Any, Tuple[int, Recv]] = {}
        for r, prog in enumerate(self.programs):
            for op in prog:
                if isinstance(op, Send):
                    if op.tag in sends:
                        raise ValueError(f"duplicate send tag {op.tag!r}")
                    sends[op.tag] = (r, op)
                elif isinstance(op, Recv):
                    if op.tag in recvs:
                        raise ValueError(f"duplicate recv tag {op.tag!r}")
                    recvs[op.tag] = (r, op)
        if set(sends) != set(recvs):
            raise ValueError(
                f"unmatched transfers: sends-only "
                f"{sorted(set(sends) - set(recvs), key=repr)}, recvs-only "
                f"{sorted(set(recvs) - set(sends), key=repr)}")
        out = []
        for tag, (src, s) in sends.items():
            dst, rv = recvs[tag]
            if s.peer != dst or rv.peer != src:
                raise ValueError(
                    f"transfer {tag!r}: send {src}->{s.peer} does not match "
                    f"recv {rv.peer}->{dst}")
            out.append(Transfer(src, dst, tag, s.frac, s.buf, rv.buf))
        return out

    def validate(self) -> "Schedule":
        """Structural checks; returns self so builders can chain.

        * every Send matches exactly one Recv (tag, src, dst consistent);
        * peers in range;
        * every buffer is written before it is read *given* the input
          binding (chunk/block/dir/value buffers count as pre-written);
        * output buffers are written somewhere.
        """
        self.transfers()   # raises on mismatches
        for r, prog in enumerate(self.programs):
            written = set(self._initial_bufs(r))
            for op in prog:
                if isinstance(op, (Send, Recv)) and not (
                        0 <= op.peer < self.n):
                    raise ValueError(f"rank {r}: peer {op.peer} out of "
                                     f"range for n={self.n}")
                for b in op.reads:
                    if b not in written:
                        raise ValueError(
                            f"rank {r}: op {op} reads unwritten buffer "
                            f"{b!r}")
                written.update(op.writes)
            for b in self._output_bufs(r):
                if b not in written:
                    raise ValueError(f"rank {r}: output buffer {b!r} is "
                                     f"never written")
        return self

    def _initial_bufs(self, rank: int) -> List[Any]:
        if self.input_kind in ("value", "array"):
            return ["in"]
        if self.input_kind == "chunks":
            return list(self.chunk_bufs)
        if self.input_kind == "blocks":
            return [("b", d) for d in range(self.n)]
        if self.input_kind == "dirs":
            return [("s", d) for d in self.out_dirs[rank]]
        return []

    def _output_bufs(self, rank: int) -> List[Any]:
        if self.output_kind == "buf":
            b = self.out_bufs[rank]
            return [] if b is None else [b]
        if self.output_kind == "concat":
            return list(self.chunk_bufs)
        if self.output_kind == "list":
            return [("g", i) for i in range(self.n)]
        if self.output_kind == "dirs":
            dirs = self.in_dirs or self.out_dirs
            return [("rv", d) for d in dirs[rank]]
        return []

    def wait_plan(self, rank: int) -> Tuple[
            Tuple[Tuple[Op, Tuple[Any, ...]], ...], Tuple[Any, ...]]:
        """Static wait plan of one rank's program.

        Whether an op must wait on an in-flight receive is a property of
        the *schedule*, not of any particular run: a buffer is pending
        exactly when an earlier ``Recv`` posted it and no op between the
        two reads it.  Returns ``(steps, tail)``: ``steps`` pairs every op
        with the (possibly empty) tuple of pending buffers it consumes, in
        posting order; ``tail`` is the receives still in flight after the
        last op — completion waits on them (barrier semantics).  Executors
        that precompute this (:mod:`repro.core.program`) wait exactly
        where the reference interpreter
        (:func:`repro.core.collectives._interpret`) would.
        """
        posted: Dict[Any, None] = {}    # insertion-ordered set
        steps: List[Tuple[Op, Tuple[Any, ...]]] = []
        for op in self.programs[rank]:
            waits = tuple(b for b in op.reads if b in posted)
            for b in waits:
                del posted[b]
            steps.append((op, waits))
            if isinstance(op, Recv):
                posted[op.buf] = None
        return tuple(steps), tuple(posted)

    # -- cost model ---------------------------------------------------------
    def cost(self, alpha: float, beta: float, size: float = 0.0, *,
             gamma: float = 0.0, link=None) -> float:
        """Predicted makespan under the α-β(-γ) model.

        ``alpha`` — per-transfer latency (s); ``beta`` — wire time per byte
        (s/B); ``size`` — the collective's nominal per-rank payload in
        bytes (an op moving/combining ``frac`` of it costs
        ``β·frac·size`` / ``γ·frac·size``); ``gamma`` — combine time per
        byte (s/B; 0 = free combines, the textbook α-β model).

        ``link`` optionally maps ``(src rank, dst rank)`` to that
        transfer's ``(α, β)`` — the heterogeneous-machine model shared
        with :func:`repro.core.simulate.schedule_tasks`; a two-tier link
        makes hierarchical schedules pay cheap intra-pod and expensive
        inter-pod constants, which is how :func:`best_schedule` compares
        flat against hierarchical candidates apples-to-apples.

        One-port evaluation over the DAG: each rank's sends serialise in
        program order (send port busy α + β·b per transfer), so do its
        receives (ingest port) and its combines (CPU, γ·b); transfers and
        combines of *independent* ops overlap freely — which is exactly
        what makes segmented schedules pipeline.  Marshalling ops
        (Copy/Pack/Unpack/Slice/Const) are free.
        """
        n = self.n
        avail: List[Dict[Any, float]] = [dict.fromkeys(
            self._initial_bufs(r), 0.0) for r in range(n)]
        port = [0.0] * n
        rport = [0.0] * n
        cpu = [0.0] * n
        arrival: Dict[Any, float] = {}
        pcs = [0] * n
        remaining = sum(len(p) for p in self.programs)
        while remaining:
            progressed = False
            for r in range(n):
                prog = self.programs[r]
                while pcs[r] < len(prog):
                    op = prog[pcs[r]]
                    env = avail[r]
                    if isinstance(op, Recv):
                        if op.tag not in arrival:
                            break               # sender not launched yet
                        a, bt = (alpha, beta) if link is None \
                            else link(op.peer, r)
                        done = max(arrival[op.tag],
                                   rport[r] + a + bt * op.frac * size)
                        rport[r] = done
                        env[op.buf] = done
                    elif isinstance(op, Send):
                        a, bt = (alpha, beta) if link is None \
                            else link(r, op.peer)
                        ready = max(env[op.buf], port[r])
                        done = ready + a + bt * op.frac * size
                        port[r] = done
                        arrival[op.tag] = done
                    elif isinstance(op, Combine):
                        ready = max(env[op.a], env[op.b], cpu[r])
                        done = ready + gamma * op.frac * size
                        cpu[r] = done
                        env[op.out] = done
                    elif isinstance(op, Copy):
                        env[op.out] = env[op.src]
                    elif isinstance(op, Pack):
                        env[op.out] = max(env[p] for p in op.parts)
                    elif isinstance(op, Unpack):
                        for o in op.outs:
                            env[o] = env[op.src]
                    elif isinstance(op, Slice):
                        env[op.out] = env[op.src]
                    elif isinstance(op, Concat):
                        env[op.out] = max(env[b] for b in op.reads)
                    elif isinstance(op, Const):
                        env[op.out] = 0.0
                    else:               # pragma: no cover - new op kinds
                        raise TypeError(f"unknown op {op!r}")
                    pcs[r] += 1
                    remaining -= 1
                    progressed = True
            if not progressed:
                stuck = [r for r in range(n)
                         if pcs[r] < len(self.programs[r])]
                raise RuntimeError(f"schedule deadlock while costing: "
                                   f"ranks {stuck} cannot progress")
        makespan = max([0.0] + port + rport + cpu + list(arrival.values()))
        # completion also waits for every rank's final buffers
        for r in range(n):
            for b in self._output_bufs(r):
                makespan = max(makespan, avail[r].get(b, 0.0))
        return makespan

    @property
    def rounds(self) -> int:
        """Critical-path transfer rounds — ``cost`` with unit latency and
        free wires/combines.  Matches the closed-form
        :func:`repro.core.collectives.n_rounds` latency model (asserted in
        tests)."""
        return int(round(self.cost(1.0, 0.0, 0.0)))

    def counts(self) -> Dict[str, int]:
        """Op-kind histogram — handy for structural tests and docs."""
        out: Dict[str, int] = {}
        for prog in self.programs:
            for op in prog:
                k = type(op).__name__
                out[k] = out.get(k, 0) + 1
        return out


# ---------------------------------------------------------------------------
# Builder plumbing
# ---------------------------------------------------------------------------
class _B:
    """Accumulates per-rank programs; ``xfer`` appends the matched
    Send/Recv pair with an auto-assigned schedule-unique tag, so transfers
    can never mismatch by construction."""

    def __init__(self, n: int) -> None:
        self.n = n
        self.programs: List[List[Op]] = [[] for _ in range(n)]
        self._tags = iter(range(10 ** 9))

    def xfer(self, src: int, dst: int, src_buf: Any, dst_buf: Any,
             frac: float = 1.0, tag: Any = None) -> Any:
        if tag is None:
            tag = next(self._tags)
        self.programs[src].append(Send(dst, src_buf, tag, frac))
        self.programs[dst].append(Recv(src, dst_buf, tag, frac))
        return tag

    def done(self, **kw: Any) -> Schedule:
        return Schedule(programs=tuple(tuple(p) for p in self.programs),
                        **kw).validate()


# ---------------------------------------------------------------------------
# Builders — each algorithm constructed ONCE as data.
# ---------------------------------------------------------------------------
def _barrier_dissemination(n: int) -> Schedule:
    b = _B(n)
    for r in range(n):
        b.programs[r].append(Const("tok", True))
    tok: List[Any] = ["tok"] * n
    k, rnd = 1, 0
    while k < n:
        nxt = []
        for r in range(n):
            # forward the previously *received* token: the dataflow edge
            # that makes round k+1 wait for round k (barrier transitivity).
            b.xfer(r, (r + k) % n, tok[r], ("m", rnd, (r + k) % n))
        for r in range(n):
            nxt.append(("m", rnd, r))
        tok = nxt
        k <<= 1
        rnd += 1
    return b.done(name="barrier", algorithm="doubling", n=n,
                  input_kind="none", output_kind="none")


def _barrier_ring(n: int) -> Schedule:
    b = _B(n)
    for r in range(n):
        b.programs[r].append(Const("tok", True))
    tok: List[Any] = ["tok"] * n
    for k in range(n - 1):
        for r in range(n):
            b.xfer(r, (r + 1) % n, tok[r], ("m", k, (r + 1) % n))
        tok = [("m", k, r) for r in range(n)]
    return b.done(name="barrier", algorithm="ring", n=n,
                  input_kind="none", output_kind="none")


def _pow2_below(n: int) -> int:
    return 1 << (n.bit_length() - 1)


def _bcast_tree(n: int, root: int) -> Schedule:
    """Binomial-tree broadcast (MPICH-style), any rank count.

    Virtual rank ``vr = (r - root) % n``; vr > 0 receives once from
    ``vr - lowbit(vr)``, then forwards down its subtrees largest-first —
    the exact wave structure of the pre-IR host generator.  Each rank
    receives exactly once, so ``("t", dst)`` tags are schedule-unique and
    the Send/Recv pair is matched by the same closed formula on both
    sides.
    """
    progs: List[List[Op]] = [[] for _ in range(n)]
    buf: List[Any] = [None] * n
    for vr in range(n):
        r = (vr + root) % n
        if vr == 0:
            buf[r] = "in"
            mask = _pow2_below(n - 1) if n > 1 else 0
        else:
            lowbit = vr & -vr
            src = ((vr - lowbit) + root) % n
            buf[r] = ("m", r)
            progs[r].append(Recv(src, buf[r], ("t", r)))
            mask = lowbit >> 1
        while mask:
            if vr + mask < n:
                dst = ((vr + mask) + root) % n
                progs[r].append(Send(dst, buf[r], ("t", dst)))
            mask >>= 1
    return Schedule(name="bcast", algorithm="doubling", n=n,
                    programs=tuple(tuple(p) for p in progs),
                    input_kind="value", output_kind="buf",
                    out_bufs=tuple(buf)).validate()


def _bcast_chain(n: int, root: int) -> Schedule:
    b = _B(n)
    buf: List[Any] = [None] * n
    buf[root] = "in"
    for step in range(n - 1):
        src = (root + step) % n
        dst = (root + step + 1) % n
        buf[dst] = ("m", dst)
        b.xfer(src, dst, buf[src], buf[dst])
    return b.done(name="bcast", algorithm="ring", n=n, input_kind="value",
                  output_kind="buf", out_bufs=tuple(buf))


def _reduce_tree(n: int, root: int) -> Schedule:
    """Binomial-tree reduction to ``root`` (commutative op).

    The mirror of :func:`_bcast_tree`: virtual rank ``vr`` whose lowest
    set bit is ``mask`` sends its accumulator to ``vr - mask`` and is
    done; survivors combine partners at increasing masks, ``acc = op(acc,
    other)`` — operand order preserved from the pre-IR generator.  Each
    rank sends at most once, so ``("t", src)`` tags are schedule-unique.
    """
    progs: List[List[Op]] = [[] for _ in range(n)]
    acc: List[Any] = ["in"] * n
    out: List[Any] = [None] * n
    for vr in range(n):
        r = (vr + root) % n
        mask = 1
        while mask < n:
            if vr & mask:
                dst = ((vr - mask) + root) % n
                progs[r].append(Send(dst, acc[r], ("t", r)))
                break
            if vr + mask < n:
                src = ((vr + mask) + root) % n
                progs[r].append(Recv(src, ("m", src), ("t", src)))
                nxt = ("a", r, mask)
                progs[r].append(Combine(nxt, acc[r], ("m", src)))
                acc[r] = nxt
            mask <<= 1
        else:
            out[r] = acc[r]
    return Schedule(name="reduce", algorithm="doubling", n=n,
                    programs=tuple(tuple(p) for p in progs),
                    input_kind="array", output_kind="buf",
                    out_bufs=tuple(out)).validate()


def _fix_recv_order(sched: Schedule) -> Schedule:
    """Move each Recv immediately before the first op that reads its
    buffer (builders emitting matched pairs in global sweeps can land the
    Recv after its consumer)."""
    progs = []
    for prog in sched.programs:
        prog = list(prog)
        changed = True
        while changed:
            changed = False
            for i, op in enumerate(prog):
                if not isinstance(op, Recv):
                    continue
                for j in range(i):
                    if op.buf in prog[j].reads:
                        prog.insert(j, prog.pop(i))
                        changed = True
                        break
                if changed:
                    break
        progs.append(tuple(prog))
    return dataclasses.replace(sched, programs=tuple(progs))


def _reduce_chain(n: int, root: int) -> Schedule:
    b = _B(n)
    acc: List[Any] = ["in"] * n
    out: List[Any] = [None] * n
    for step in range(n - 1):
        src = (root + n - 1 - step) % n     # vr = n-1-step
        dst = (root + n - 2 - step) % n
        b.xfer(src, dst, acc[src], ("m", src))
        nxt = ("a", dst)
        b.programs[dst].append(Combine(nxt, acc[dst], ("m", src)))
        acc[dst] = nxt
    out[root] = acc[root]
    return b.done(name="reduce", algorithm="ring", n=n, input_kind="array",
                  output_kind="buf", out_bufs=tuple(out))


def _chunk_names(n: int, segments: int) -> List[Any]:
    if segments == 1:
        return [("c", i) for i in range(n)]
    return [("c", i, s) for i in range(n) for s in range(segments)]


def _allreduce_ring(n: int, segments: int = 1) -> Schedule:
    """Ring allreduce: reduce-scatter rounds then allgather rounds.

    With ``segments=S > 1`` every chunk is further split into S segments
    whose rounds interleave — the combine of segment ``s`` overlaps the
    transport of segment ``s+1`` on the cost model's DAG, and the host
    interpreter/the lowering execute the same pipelined order.
    """
    b = _B(n)
    S = segments
    cur: Dict[Tuple[int, int, int], Any] = {}   # (rank, chunk, seg) -> buf
    for r in range(n):
        for i in range(n):
            for s in range(S):
                cur[(r, i, s)] = ("c", i, s) if S > 1 else ("c", i)
    frac = 1.0 / (n * S)
    for k in range(n - 1):                      # reduce-scatter leg
        for s in range(S):
            for r in range(n):
                i_send = (r - 1 - k) % n
                b.xfer(r, (r + 1) % n, cur[(r, i_send, s)],
                       ("m", "s", k, s, (r + 1) % n), frac)
            for r in range(n):
                i = (r - 2 - k) % n
                nxt = ("a", k, s, i)
                b.programs[r].append(
                    Combine(nxt, cur[(r, i, s)], ("m", "s", k, s, r),
                            frac))
                cur[(r, i, s)] = nxt
    for k in range(n - 1):                      # allgather leg
        for s in range(S):
            for r in range(n):
                i_send = (r - k) % n
                b.xfer(r, (r + 1) % n, cur[(r, i_send, s)],
                       ("m", "g", k, s, (r + 1) % n), frac)
            for r in range(n):
                i = (r - k - 1) % n
                nxt = ("m", "g", k, s, r)
                cur[(r, i, s)] = nxt
    # canonicalise chunk buffers for the concat output
    chunk_bufs = _chunk_names(n, S)
    for r in range(n):
        for i in range(n):
            for s in range(S):
                want = ("c", i, s) if S > 1 else ("c", i)
                have = cur[(r, i, s)]
                if have != want:
                    b.programs[r].append(Copy(want, have))
    sched = Schedule(name="allreduce", algorithm="ring", n=n,
                     programs=tuple(tuple(p) for p in b.programs),
                     input_kind="chunks", output_kind="concat",
                     segments=S, chunk_bufs=tuple(chunk_bufs))
    return _fix_recv_order(sched).validate()


def _allreduce_doubling(n: int) -> Schedule:
    """Recursive doubling with fold/unfold for non-power-of-two ``n``."""
    b = _B(n)
    pow2 = _pow2_below(n)
    rem = n - pow2
    acc: List[Any] = ["in"] * n
    out: List[Any] = [None] * n
    members = []            # butterfly participants with their virtual rank
    for r in range(n):
        if r < 2 * rem:
            if r % 2:
                b.xfer(r, r - 1, acc[r], ("m", "fold", r - 1))
            else:
                nxt = ("a", "fold", r)
                b.programs[r].append(
                    Combine(nxt, acc[r], ("m", "fold", r)))
                acc[r] = nxt
                members.append((r, r // 2))
        else:
            members.append((r, r - rem))
    mask = 1
    while mask < pow2:
        for r, vr in members:
            partner_vr = vr ^ mask
            partner = partner_vr * 2 if partner_vr < rem \
                else partner_vr + rem
            b.xfer(r, partner, acc[r], ("m", "x", mask, partner))
        for r, vr in members:
            nxt = ("a", "x", mask, r)
            b.programs[r].append(Combine(nxt, acc[r], ("m", "x", mask, r)))
            acc[r] = nxt
        mask <<= 1
    for r in range(n):
        if r < 2 * rem and r % 2:
            out[r] = ("m", "unfold", r)
        else:
            out[r] = acc[r]
            if r < 2 * rem:
                b.xfer(r, r + 1, acc[r], ("m", "unfold", r + 1))
    sched = Schedule(name="allreduce", algorithm="doubling", n=n,
                     programs=tuple(tuple(p) for p in b.programs),
                     input_kind="array", output_kind="buf",
                     out_bufs=tuple(out))
    return _fix_recv_order(sched).validate()


def _allgather_ring(n: int, segments: int = 1) -> Schedule:
    """Ring allgather; with ``segments=S > 1`` every contribution is
    sliced into S segments forwarded as independent pipelined rings (the
    store-and-forward segmentation), reassembled per source rank by a
    trailing :class:`Concat` shaped like the local ``"in"``."""
    b = _B(n)
    S = segments
    if S == 1:
        for r in range(n):
            b.programs[r].append(Copy(("g", r), "in"))
        for k in range(n - 1):
            for r in range(n):
                b.xfer(r, (r + 1) % n, ("g", (r - k) % n),
                       ("m", k, (r + 1) % n))
            for r in range(n):
                b.programs[r].append(
                    Copy(("g", (r - k - 1) % n), ("m", k, r)))
        sched = Schedule(name="allgather", algorithm="ring", n=n,
                         programs=tuple(tuple(p) for p in b.programs),
                         input_kind="value", output_kind="list")
        return _fix_recv_order(sched).validate()
    for r in range(n):
        for s in range(S):
            b.programs[r].append(Slice(("gs", r, s), "in", S, s))
    frac = 1.0 / S
    for k in range(n - 1):
        for s in range(S):
            for r in range(n):
                b.xfer(r, (r + 1) % n, ("gs", (r - k) % n, s),
                       ("m", k, s, (r + 1) % n), frac)
            for r in range(n):
                b.programs[r].append(
                    Copy(("gs", (r - k - 1) % n, s), ("m", k, s, r)))
    for r in range(n):
        for i in range(n):
            b.programs[r].append(
                Concat(("g", i), tuple(("gs", i, s) for s in range(S)),
                       like="in"))
    sched = Schedule(name="allgather", algorithm="ring", n=n,
                     programs=tuple(tuple(p) for p in b.programs),
                     input_kind="value", output_kind="list", segments=S)
    return _fix_recv_order(sched).validate()


def _allgather_bruck(n: int) -> Schedule:
    """Bruck allgather: ⌈log2 n⌉ rounds, any rank count.  ``("a", j)`` is
    the j-th item of the rank's growing accumulator (item j = rank
    ``(r + j) % n``'s contribution)."""
    b = _B(n)
    for r in range(n):
        b.programs[r].append(Copy(("a", 0), "in"))
    length = 1
    k = 1
    while k < n:
        cnt = min(k, n - k)
        for r in range(n):
            parts = tuple(("a", j) for j in range(cnt))
            b.programs[r].append(Pack(("p", k), parts))
            b.xfer(r, (r - k) % n, ("p", k), ("m", k, (r - k) % n),
                   frac=float(cnt))
        for r in range(n):
            outs = tuple(("a", length + j) for j in range(cnt))
            b.programs[r].append(Unpack(outs, ("m", k, r)))
        length += cnt
        k <<= 1
    for r in range(n):
        for i in range(n):
            b.programs[r].append(Copy(("g", i), ("a", (i - r) % n)))
    sched = Schedule(name="allgather", algorithm="doubling", n=n,
                     programs=tuple(tuple(p) for p in b.programs),
                     input_kind="value", output_kind="list")
    return _fix_recv_order(sched).validate()


def _reduce_scatter_ring(n: int, segments: int = 1) -> Schedule:
    """Ring reduce-scatter; ``segments=S > 1`` pipelines exactly like the
    segmented allreduce's reduce-scatter leg — the combine of segment
    ``s`` overlaps the transport of segment ``s+1`` — and a trailing
    :class:`Concat` reassembles each rank's owned chunk (bit-identical to
    the unsegmented chunk: ``array_split`` composes with itself)."""
    b = _B(n)
    S = segments
    cur: Dict[Tuple[int, int, int], Any] = {}
    for r in range(n):
        for i in range(n):
            for s in range(S):
                cur[(r, i, s)] = ("c", i, s) if S > 1 else ("c", i)
    frac = 1.0 / (n * S)
    for k in range(n - 1):
        for s in range(S):
            for r in range(n):
                b.xfer(r, (r + 1) % n, cur[(r, (r - 1 - k) % n, s)],
                       ("m", k, s, (r + 1) % n), frac)
            for r in range(n):
                i = (r - 2 - k) % n
                nxt = ("a", k, s, i)
                b.programs[r].append(
                    Combine(nxt, cur[(r, i, s)], ("m", k, s, r), frac))
                cur[(r, i, s)] = nxt
    if S == 1:
        out = tuple(cur[(r, r, 0)] for r in range(n))
    else:
        for r in range(n):
            b.programs[r].append(
                Concat(("rs", r), tuple(cur[(r, r, s)] for s in range(S))))
        out = tuple(("rs", r) for r in range(n))
    sched = Schedule(name="reduce_scatter", algorithm="ring", n=n,
                     programs=tuple(tuple(p) for p in b.programs),
                     input_kind="chunks", output_kind="buf", segments=S,
                     out_bufs=out, chunk_bufs=tuple(_chunk_names(n, S)))
    return _fix_recv_order(sched).validate()


def _reduce_scatter_doubling(n: int) -> Schedule:
    """Doubling allreduce + slice (recursive halving's power-of-two block
    mapping clashes with n-way output blocks — same trade as before the
    IR refactor)."""
    base = _allreduce_doubling(n)
    progs = []
    out = []
    for r, prog in enumerate(base.programs):
        prog = list(prog)
        src = base.out_bufs[r]
        prog.append(Slice(("rs", r), src, n, r))
        progs.append(tuple(prog))
        out.append(("rs", r))
    return Schedule(name="reduce_scatter", algorithm="doubling", n=n,
                    programs=tuple(progs), input_kind="array",
                    output_kind="buf", out_bufs=tuple(out)).validate()


def _alltoall_pairwise(n: int) -> Schedule:
    b = _B(n)
    for r in range(n):
        b.programs[r].append(Copy(("g", r), ("b", r)))
    for k in range(1, n):
        for r in range(n):
            dst = (r + k) % n
            b.xfer(r, dst, ("b", dst), ("m", k, dst))
        for r in range(n):
            src = (r - k) % n
            b.programs[r].append(Copy(("g", src), ("m", k, r)))
    sched = Schedule(name="alltoall", algorithm="ring", n=n,
                     programs=tuple(tuple(p) for p in b.programs),
                     input_kind="blocks", output_kind="list")
    return _fix_recv_order(sched).validate()


def _alltoall_bruck(n: int) -> Schedule:
    """Bruck all-to-all: rotate, ⌈log2 n⌉ bit-rounds, inverse rotate."""
    b = _B(n)
    for r in range(n):
        for j in range(n):
            b.programs[r].append(Copy(("t", j), ("b", (r + j) % n)))
    k = 1
    while k < n:
        idxs = [j for j in range(n) if j & k]
        for r in range(n):
            b.programs[r].append(
                Pack(("p", k), tuple(("t", j) for j in idxs)))
            b.xfer(r, (r + k) % n, ("p", k), ("m", k, (r + k) % n),
                   frac=float(len(idxs)))
        for r in range(n):
            b.programs[r].append(
                Unpack(tuple(("t2", k, j) for j in idxs), ("m", k, r)))
            for j in idxs:
                b.programs[r].append(Copy(("t", j), ("t2", k, j)))
        k <<= 1
    for r in range(n):
        for i in range(n):
            b.programs[r].append(Copy(("g", i), ("t", (r - i) % n)))
    sched = Schedule(name="alltoall", algorithm="doubling", n=n,
                     programs=tuple(tuple(p) for p in b.programs),
                     input_kind="blocks", output_kind="list")
    return _fix_recv_order(sched).validate()


# ---------------------------------------------------------------------------
# Public constructors (cached: schedules are immutable data)
# ---------------------------------------------------------------------------
def build(name: str, algorithm: str, n: int, *, root: int = 0,
          segments: int = 1) -> Schedule:
    """Build (or fetch the cached) schedule for one collective.

    ``segments > 1`` is supported for ``("allreduce", "ring")`` — the
    segmented/pipelined large-payload schedule; every other (name,
    algorithm) pair takes ``segments=1``.  Identical parameters return
    the identical (immutable) object.
    """
    return _build_cached(name, algorithm, int(n), int(root), int(segments))


@functools.lru_cache(maxsize=512)
def _build_cached(name: str, algorithm: str, n: int, root: int,
                  segments: int) -> Schedule:
    if n < 1:
        raise ValueError(f"need at least one rank, got n={n}")
    if name not in COLLECTIVES:
        raise ValueError(f"unknown collective {name!r}; "
                         f"one of {sorted(COLLECTIVES)}")
    if algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm!r}; "
                         f"one of {sorted(ALGORITHMS)}")
    if not 0 <= root < n:
        raise ValueError(f"root {root} out of range for n={n}")
    if segments < 1:
        raise ValueError(f"segments must be >= 1, got {segments}")
    if segments > 1 and not (algorithm == "ring" and name in (
            "allreduce", "allgather", "reduce_scatter")):
        raise ValueError("segmented schedules are only defined for the "
                         "ring allreduce/allgather/reduce_scatter")
    if n == 1:
        return _trivial(name, algorithm)
    if name == "barrier":
        return (_barrier_dissemination if algorithm == "doubling"
                else _barrier_ring)(n)
    if name == "bcast":
        return (_bcast_tree if algorithm == "doubling"
                else _bcast_chain)(n, root)
    if name == "reduce":
        return (_reduce_tree if algorithm == "doubling"
                else _reduce_chain)(n, root)
    if name == "allreduce":
        if algorithm == "doubling":
            return _allreduce_doubling(n)
        return _allreduce_ring(n, segments)
    if name == "allgather":
        return (_allgather_bruck(n) if algorithm == "doubling"
                else _allgather_ring(n, segments))
    if name == "reduce_scatter":
        return (_reduce_scatter_doubling(n) if algorithm == "doubling"
                else _reduce_scatter_ring(n, segments))
    return (_alltoall_bruck if algorithm == "doubling"
            else _alltoall_pairwise)(n)


def _trivial(name: str, algorithm: str) -> Schedule:
    """Single-rank schedules: no transfers, identity outputs."""
    prog: Tuple[Op, ...] = ()
    kw: Dict[str, Any] = {}
    if name == "barrier":
        ik, ok = "none", "none"
    elif name == "bcast":
        ik, ok = "value", "buf"
        kw["out_bufs"] = ("in",)
    elif name == "reduce":
        ik, ok = "array", "buf"
        kw["out_bufs"] = ("in",)
    elif name == "allreduce":
        ik, ok = "array", "buf"
        kw["out_bufs"] = ("in",)
    elif name == "allgather":
        ik, ok = "value", "list"
        prog = (Copy(("g", 0), "in"),)
    elif name == "reduce_scatter":
        ik, ok = "array", "buf"
        prog = (Slice(("rs", 0), "in", 1, 0),)
        kw["out_bufs"] = (("rs", 0),)
    else:   # alltoall
        ik, ok = "blocks", "list"
        prog = (Copy(("g", 0), ("b", 0)),)
    return Schedule(name=name, algorithm=algorithm, n=1,
                    programs=(prog,), input_kind=ik, output_kind=ok,
                    **kw).validate()


@functools.lru_cache(maxsize=256)
def build_neighbor(topology: Tuple[Tuple[Tuple[Any, int], ...], ...],
                   in_topology: Optional[
                       Tuple[Tuple[Any, ...], ...]] = None) -> Schedule:
    """Neighbourhood all-to-all over a fixed topology.

    ``topology[r]`` is rank r's persistent *send* neighbour list
    ``(((dim, ±1), neighbour), ...)`` — the shape produced by
    :meth:`repro.core.tac.CartGroup.neighbor_dirs` /
    :meth:`repro.core.tac.CartGroup.topology`.  Rank r sends its
    ``("s", d)`` buffer toward each direction ``d``; the payload lands in
    the neighbour's ``("rv", opp(d))`` buffer where ``opp(d) = (d[0],
    -d[1])``.  By default receives mirror sends (reciprocity: if r's
    ``d``-neighbour is q, then q's ``-d``-neighbour is r).

    For a **directed** topology (one-way edges —
    :meth:`repro.core.tac.DistGraphGroup.in_topology`), pass
    ``in_topology[r]`` = rank r's receive-direction labels.  The derived
    arrivals are validated against the declaration: every send must land
    on a declared in-direction of its destination, and every declared
    in-direction must be fed by exactly one send.
    """
    n = len(topology)
    b = _B(n)
    derived_in: List[List[Any]] = [[] for _ in range(n)]
    for r, dirs in enumerate(topology):
        for d, nbr in dirs:
            dim, disp = d
            opp = (dim, -disp)
            b.xfer(r, nbr, ("s", d), ("rv", opp), tag=("n", d, r))
            derived_in[nbr].append(opp)
    out_dirs = tuple(tuple(d for d, _ in dirs) for dirs in topology)
    in_dirs: Tuple[Tuple[Any, ...], ...] = ()
    if in_topology is not None:
        if len(in_topology) != n:
            raise ValueError(f"in_topology covers {len(in_topology)} ranks, "
                             f"topology has {n}")
        in_dirs = tuple(tuple(dirs) for dirs in in_topology)
        for r in range(n):
            declared, derived = list(in_dirs[r]), derived_in[r]
            if sorted(declared, key=repr) != sorted(derived, key=repr):
                raise ValueError(
                    f"rank {r}: declared in-directions {declared} do not "
                    f"match the directions arriving from the send "
                    f"topology {sorted(derived, key=repr)}")
    sched = Schedule(name="neighbor_alltoall", algorithm="neighbor", n=n,
                     programs=tuple(tuple(p) for p in b.programs),
                     input_kind="dirs", output_kind="dirs",
                     out_dirs=out_dirs, in_dirs=in_dirs)
    return _fix_recv_order(sched).validate()


# ---------------------------------------------------------------------------
# Hierarchical composition: one flat schedule spanning two mesh axes
# ---------------------------------------------------------------------------
def _embed(b: _B, sub: Schedule, ranks: Sequence[int], ns: Any, *,
           inputs: Dict[int, Any], frac_scale: float = 1.0) -> List[Any]:
    """Splice ``sub``'s per-rank programs into builder ``b``.

    ``ranks[sr]`` maps sub-rank ``sr`` to its global rank; ``inputs[sr]``
    binds the sub-schedule's ``"in"`` buffer to an existing global buffer;
    internal buffers and tags are namespaced under ``ns`` (which must be
    unique per embedding) so sibling embeddings can never collide;
    ``frac_scale`` rescales per-op payload fractions to the enclosing
    schedule's nominal size.  Supports ``array``/``value`` input and
    ``buf`` output sub-schedules (the reductions); returns the renamed
    per-sub-rank output buffers.
    """
    if sub.input_kind not in ("array", "value") or sub.output_kind != "buf":
        raise ValueError(f"cannot embed a {sub.input_kind!r}->"
                         f"{sub.output_kind!r} schedule")

    def rename(sr: int, buf: Any) -> Any:
        return inputs[sr] if buf == "in" else (ns, buf)

    for sr, prog in enumerate(sub.programs):
        gr = ranks[sr]
        for op in prog:
            if isinstance(op, Send):
                b.programs[gr].append(Send(
                    ranks[op.peer], rename(sr, op.buf), (ns, op.tag),
                    op.frac * frac_scale))
            elif isinstance(op, Recv):
                b.programs[gr].append(Recv(
                    ranks[op.peer], rename(sr, op.buf), (ns, op.tag),
                    op.frac * frac_scale))
            elif isinstance(op, Combine):
                b.programs[gr].append(Combine(
                    rename(sr, op.out), rename(sr, op.a), rename(sr, op.b),
                    op.frac * frac_scale))
            elif isinstance(op, Copy):
                b.programs[gr].append(Copy(rename(sr, op.out),
                                           rename(sr, op.src)))
            elif isinstance(op, Const):
                b.programs[gr].append(Const(rename(sr, op.out), op.value))
            else:
                raise ValueError(f"cannot embed op {op!r}")
    return [rename(sr, sub.out_bufs[sr]) for sr in range(sub.n)]


def build_hierarchical(intra: int, inter: int, *,
                       inter_algorithm: str = "doubling") -> Schedule:
    """Hierarchical allreduce over a 2-D (inter × intra) rank grid.

    One FLAT schedule over ``n = intra·inter`` ranks (global rank
    ``r = pod·intra + local``) composing three stages:

    1. ring **reduce-scatter** inside each pod (``intra-1`` rounds of
       ``m/intra`` bytes — after it, local rank ``l`` owns the pod-sum of
       chunk ``l``);
    2. recursive-doubling **allreduce** of each owned chunk across pods
       (every pod's rank ``l`` butterflies chunk ``l`` with its peers —
       the :func:`_allreduce_doubling` sub-schedule embedded per chunk via
       :func:`_embed`, fold/unfold handling any pod count);
    3. ring **allgather** inside each pod (shard-wise broadcast back —
       ``intra-1`` more rounds), so every rank finishes with the global
       sum.

    Because the result is an ordinary validated :class:`Schedule`, all
    four consumers run it unchanged: the Level-A interpreter
    (:func:`repro.core.collectives._interpret`), the Level-B two-axis
    lowering (:func:`repro.core.lowering.lower_allreduce` — intra-axis
    ppermute rounds, inter-axis butterfly or fused psum), the α-β
    :meth:`Schedule.cost`, and the discrete-event replay
    (:func:`repro.core.simulate.schedule_tasks`).  ``Schedule.axes``
    records the ``(("inter", n_e), ("intra", n_i))`` factorisation the
    lowering and the two-tier link model key off.
    """
    return _hier_cached(int(intra), int(inter), inter_algorithm)


@functools.lru_cache(maxsize=128)
def _hier_cached(intra: int, inter: int, inter_algorithm: str) -> Schedule:
    if intra < 1 or inter < 1:
        raise ValueError(f"need positive axis sizes, got intra={intra}, "
                         f"inter={inter}")
    if inter_algorithm != "doubling":
        raise ValueError(f"inter stage supports 'doubling' (butterfly / "
                         f"fused psum at Level B), got {inter_algorithm!r}")
    n = intra * inter
    b = _B(n)
    frac = 1.0 / intra
    cur: Dict[Tuple[int, int], Any] = {(r, i): ("c", i)
                                       for r in range(n)
                                       for i in range(intra)}
    # stage 1 — intra ring reduce-scatter within each pod
    for k in range(intra - 1):
        for r in range(n):
            pod, loc = divmod(r, intra)
            dst = pod * intra + (loc + 1) % intra
            b.xfer(r, dst, cur[(r, (loc - 1 - k) % intra)],
                   ("m", "rs", k, dst), frac)
        for r in range(n):
            _, loc = divmod(r, intra)
            i = (loc - 2 - k) % intra
            nxt = ("a", "rs", k, i)
            b.programs[r].append(
                Combine(nxt, cur[(r, i)], ("m", "rs", k, r), frac))
            cur[(r, i)] = nxt
    # stage 2 — inter allreduce of each rank's owned chunk across pods
    if inter > 1:
        sub = build("allreduce", inter_algorithm, inter)
        for loc in range(intra):
            ranks = tuple(pod * intra + loc for pod in range(inter))
            outs = _embed(b, sub, ranks, ("x", loc),
                          inputs={sr: cur[(gr, loc)]
                                  for sr, gr in enumerate(ranks)},
                          frac_scale=frac)
            for sr, gr in enumerate(ranks):
                cur[(gr, loc)] = outs[sr]
    # stage 3 — intra ring allgather (shard-wise broadcast back down)
    for k in range(intra - 1):
        for r in range(n):
            pod, loc = divmod(r, intra)
            dst = pod * intra + (loc + 1) % intra
            b.xfer(r, dst, cur[(r, (loc - k) % intra)],
                   ("m", "ag", k, dst), frac)
        for r in range(n):
            _, loc = divmod(r, intra)
            cur[(r, (loc - k - 1) % intra)] = ("m", "ag", k, r)
    # canonicalise chunk buffers for the concat output
    for r in range(n):
        for i in range(intra):
            if cur[(r, i)] != ("c", i):
                b.programs[r].append(Copy(("c", i), cur[(r, i)]))
    sched = Schedule(name="allreduce", algorithm="hierarchical", n=n,
                     programs=tuple(tuple(p) for p in b.programs),
                     input_kind="chunks", output_kind="concat",
                     chunk_bufs=tuple(("c", i) for i in range(intra)),
                     n_chunks=intra,
                     axes=(("inter", inter), ("intra", intra)))
    return _fix_recv_order(sched).validate()


# ---------------------------------------------------------------------------
# Calibrated constants (tools/calibrate.py output)
# ---------------------------------------------------------------------------
def load_calibration(path: Any = "CALIBRATION.json",
                     family: Optional[str] = None) -> Dict[str, float]:
    """Read α/β/γ least-squares fitted by ``tools/calibrate.py``.

    Returns exactly ``{"alpha", "beta", "gamma"}`` — ready to splat into
    :func:`best_schedule`/:meth:`Schedule.cost` keyword arguments, and
    what ``Collectives(comm, calibration=path)`` consumes so
    ``algorithm="auto"`` selects under measured rather than nominal
    constants.  (The calibration file also carries a per-call
    ``overhead`` term the fit absorbs; schedule costs deliberately
    exclude it.)

    ``family`` selects one of the per-family fits (``"inter"`` is the
    inter-pod transport measured by the butterfly legs of
    ``benchmarks/overlap_bench.py`` — the constants the two-tier
    hierarchical candidate of :func:`best_schedule` pays for cross-pod
    hops); ``None`` keeps the top-level global fit.  Raises ``KeyError``
    when the requested family has not been calibrated yet.
    """
    import json
    import pathlib
    data = json.loads(pathlib.Path(path).read_text())
    if family is not None:
        fams = data.get("families", {})
        if family not in fams:
            raise KeyError(f"no calibrated family {family!r} in {path} "
                           f"(have {sorted(fams)})")
        data = fams[family]
    return {k: float(data[k]) for k in ("alpha", "beta", "gamma")}


# ---------------------------------------------------------------------------
# α-β driven selection
# ---------------------------------------------------------------------------
def best_schedule(name: str, n: int, size: float, *, alpha: float,
                  beta: float, gamma: float = 0.0, root: int = 0,
                  segment_choices: Sequence[int] = (1, 2, 4, 8),
                  intra: Optional[int] = None,
                  inter_alpha: Optional[float] = None,
                  inter_beta: Optional[float] = None) -> Schedule:
    """Pick algorithm AND segment count by minimum predicted cost.

    The α-β replacement for choosing by bare round counts: latency-bound
    payloads pick ``doubling`` (⌈log2 n⌉ rounds), bandwidth-bound ones
    pick ``ring`` (2(n-1) rounds of size/n), and — with a combine cost
    ``gamma > 0`` — large ring allreduces segment so combine pipelines
    against transport.  Selections are cached (the cost() DAG walks are
    pure Python): a per-iteration ``algorithm="auto"`` collective pays
    the evaluation once, not once per rank per posting.

    ``intra`` declares a pod structure (``intra`` consecutive ranks per
    pod): every candidate is then costed under a **two-tier link** —
    intra-pod hops pay (``alpha``, ``beta``), cross-pod hops pay
    (``inter_alpha``, ``inter_beta``; calibrate via
    ``load_calibration(path, family="inter")``, defaulting to the base
    constants) — and for the allreduce the composed
    :func:`build_hierarchical` schedule joins the candidate set, so a
    pod-aware machine picks the hierarchical schedule exactly when the
    inter constants make flat rings lose.
    """
    if intra is not None:
        intra = int(intra)
        if intra < 2 or n % intra or n // intra < 2:
            intra = None        # no real pod structure at this size
    return _best_cached(name, int(n), float(size), float(alpha),
                        float(beta), float(gamma), int(root),
                        tuple(int(s) for s in segment_choices), intra,
                        None if inter_alpha is None else float(inter_alpha),
                        None if inter_beta is None else float(inter_beta))


@functools.lru_cache(maxsize=1024)
def _best_cached(name: str, n: int, size: float, alpha: float, beta: float,
                 gamma: float, root: int,
                 segment_choices: Tuple[int, ...],
                 intra: Optional[int] = None,
                 inter_alpha: Optional[float] = None,
                 inter_beta: Optional[float] = None) -> Schedule:
    candidates: List[Schedule] = []
    for alg in ALGORITHMS:
        candidates.append(build(name, alg, n, root=root))
        if alg == "ring" and name in ("allreduce", "allgather",
                                      "reduce_scatter"):
            for s in segment_choices:
                if s > 1:
                    candidates.append(build(name, alg, n, root=root,
                                            segments=s))
    link = None
    if intra is not None:
        if name == "allreduce":
            candidates.append(build_hierarchical(intra, n // intra))
        ia = alpha if inter_alpha is None else inter_alpha
        ib = beta if inter_beta is None else inter_beta

        def link(src, dst):
            return (alpha, beta) if src // intra == dst // intra \
                else (ia, ib)
    return min(candidates,
               key=lambda s: s.cost(alpha, beta, size, gamma=gamma,
                                    link=link))
