"""Level-B executor: lower schedule IR to in-graph XLA collectives.

The second consumer of :mod:`repro.core.schedule` (the first is the host
interpreter in :mod:`repro.core.collectives`): the SAME schedule object
that the host progress engine interprets op-by-op is mapped here onto
JAX primitives inside ``shard_map``-manual regions, where "task
dependencies" are HLO dataflow edges and "the scheduler" is XLA's
latency-hiding scheduler.

Three lowering strategies, chosen by the schedule:

* **Explicit rounds** — ring (any rank count, any segment count) and
  recursive-doubling (power-of-two rank counts) allreduces become
  ``lax.ppermute`` rounds whose count and order mirror the schedule's
  transfer structure exactly (``2(n-1)·S`` ring rounds, ``log2 n``
  butterfly rounds; asserted against ``Schedule`` op counts in tests).
  Segmented schedules emit independent per-segment round chains with no
  artificial dependencies between them, so XLA overlaps the combine of
  segment *k* with the transport of segment *k+1* — the in-graph
  realisation of the pipelined schedule.

* **Fused node** — ``algorithm="native"`` lowers the whole allreduce to
  one ``lax.psum``; XLA's own combiner picks the wire schedule.  This is
  what :func:`repro.core.overlap.sync_grads` uses by default, which keeps
  the bucketed/sentinel HLO (one ``all-reduce`` per bucket, same order)
  byte-compatible with the pre-IR code.

* **Neighbourhood** — a :func:`repro.core.schedule.build_neighbor`
  schedule lowers to one ``ppermute`` per direction whose permutation
  pairs are read straight off the schedule's transfers; ranks missing a
  direction (non-periodic boundaries) simply have no pair and XLA
  delivers zeros — which is how
  :func:`repro.core.overlap.halo_exchange_rows` gets its zero boundary
  halos without explicit masking.

* **Hierarchical two-axis** — a
  :func:`repro.core.schedule.build_hierarchical` schedule lowers over TWO
  mesh axes ``(inter, intra)``: the intra-axis ring reduce-scatter and
  allgather stages become explicit ``ppermute`` rounds along the intra
  axis, and the inter stage becomes the recursive-doubling butterfly
  along the inter axis (power-of-two pod counts) or one fused
  ``lax.psum`` of the owned chunk (any pod count) — the same three-stage
  composition the host interpreter runs, reading its structure off the
  schedule's ``axes`` metadata.

In-graph lowering restrictions (by construction of the substrate): the
combining operator is addition (the gradient/residual case), payloads are
dense arrays, and flat explicit-round lowerings run over ONE mesh axis —
``native`` takes an axis tuple, and ``hierarchical`` takes exactly two
axes in ``(inter, intra)`` order.

**Pallas executor tier** (``stage_impl=``): the elementwise stages
between ppermute rounds — reduce-scatter combine, allgather install,
wire cast/dequant — are memory-bound work that unfused XLA round-trips
through HBM once per stage.  ``stage_impl="pallas"`` routes them through
the fused single-pass kernels in :mod:`repro.kernels.collective_stages`
(``"pallas_interpret"`` for CPU parity runs, ``"ref"`` for the jnp
oracle); ``stage_impl=None`` keeps the plain XLA elementwise path
byte-for-byte.  ``stage_wire="bf16"``/``"int8"`` (formerly spelled
``wire=``; see :class:`repro.core.options.CollectiveOptions`)
additionally narrows the ring
transport dtype (explicit-round ring only): reduce-scatter rounds
quantise the outgoing chunk and the fused combine dequantises while
accumulating; the allgather leg quantises each reduced chunk ONCE at its
owner, forwards the wire payload around the whole ring, and every rank
dequantises all chunks at the end — so all ranks compute bit-identical
results from the same wire bytes.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size
from ..kernels import ops as kernel_ops
from . import schedule as schedule_ir
from .options import CollectiveOptions, renamed_kwarg
from .schedule import Schedule, Send

Axes = Union[str, Sequence[str]]

_WIRE_DTYPES = {"bf16": jnp.bfloat16, "int8": jnp.int8}


def _check_stage_opts(algorithm: str, stage_impl: Optional[str],
                      wire: Optional[str]) -> None:
    if stage_impl not in (None, "pallas", "pallas_interpret", "ref"):
        raise ValueError(f"unknown stage_impl {stage_impl!r}")
    if wire is None:
        return
    if wire not in _WIRE_DTYPES:
        raise ValueError(f"unknown stage_wire dtype {wire!r}; choose from "
                         f"{sorted(_WIRE_DTYPES)}")
    if stage_impl is None:
        raise ValueError("stage_wire= needs a fused stage tier; pass "
                         "stage_impl=")
    if algorithm != "ring":
        raise ValueError(f"wire cast covers explicit ring rounds only, "
                         f"not algorithm={algorithm!r}")


def _single_axis(axis_name: Axes, what: str) -> str:
    if isinstance(axis_name, str):
        return axis_name
    axes = tuple(axis_name)
    if len(axes) != 1:
        raise ValueError(f"{what} lowers over a single mesh axis, got "
                         f"{axes}; use algorithm='native' for axis tuples")
    return axes[0]


def _two_axes(axis_name: Axes) -> Tuple[str, str]:
    """``(inter, intra)`` mesh axes of a hierarchical lowering."""
    axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    if len(axes) != 2:
        raise ValueError(f"hierarchical allreduce lowers over exactly two "
                         f"mesh axes (inter, intra), got {axes}")
    return axes[0], axes[1]


def _check_world(sched: Schedule, axis_name: str) -> None:
    n = axis_size(axis_name)
    if sched.n != n:
        raise ValueError(f"schedule is for {sched.n} ranks but axis "
                         f"{axis_name!r} has {n} shards")


def sends_per_rank(sched: Schedule) -> int:
    """Transfer rounds each rank issues — the lowered ppermute count per
    explicit-round leg (structural-equivalence hook for tests)."""
    return max(sum(isinstance(op, Send) for op in prog)
               for prog in sched.programs)


# ---------------------------------------------------------------------------
# Allreduce lowerings
# ---------------------------------------------------------------------------
def allreduce(x: jax.Array, axes: Axes, *,
              algorithm: Optional[str] = None, segments: int = 1,
              sched: Optional[Schedule] = None,
              stage_impl: Optional[str] = None,
              stage_wire: Optional[str] = None,
              wire: Optional[str] = None,
              options: Optional[CollectiveOptions] = None) -> jax.Array:
    """Sum-allreduce ``x`` over ``axes`` with a chosen schedule.

    ``algorithm="native"`` (the default) emits one fused ``lax.psum``
    node (XLA picks the rounds); ``"ring"``/``"doubling"`` build (or
    take) a schedule and emit its explicit ppermute rounds.  Must be
    called inside ``shard_map`` manual over ``axes``.

    ``stage_impl`` routes the between-round elementwise stages through
    the fused Pallas tier (``"pallas"``/``"pallas_interpret"``/``"ref"``;
    ``None`` keeps the plain XLA path).  ``stage_wire`` narrows the ring
    transport dtype (``"bf16"``/``"int8"``; needs ``stage_impl``, ring
    algorithm only).  ``wire=`` is the deprecated spelling of
    ``stage_wire=``; an explicit :class:`CollectiveOptions` spec is
    accepted as ``options=``.
    """
    stage_wire = renamed_kwarg("wire", wire, "stage_wire", stage_wire)
    algorithm, segments, stage_impl, stage_wire = CollectiveOptions.merge(
        options, algorithm=algorithm, segments=segments,
        stage_impl=stage_impl, stage_wire=stage_wire)
    if algorithm is None:
        algorithm = "native"
    if sched is None and algorithm == "native":
        if stage_impl is not None or stage_wire is not None:
            raise ValueError("native lowering is one fused psum node — "
                             "no stages to fuse; drop "
                             "stage_impl=/stage_wire=")
        return lax.psum(x, tuple(axes) if not isinstance(axes, str)
                        else (axes,))
    _check_stage_opts(algorithm if sched is None else sched.algorithm,
                      stage_impl, stage_wire)
    if sched is None and algorithm == "hierarchical":
        if segments != 1:
            # mirror Collectives._resolve: the composed schedule is fixed,
            # silently dropping segments would fake pipelining.
            raise ValueError("hierarchical allreduce fixes the composed "
                             "schedule; drop segments=")
        inter_axis, intra_axis = _two_axes(axes)
        sched = schedule_ir.build_hierarchical(axis_size(intra_axis),
                                               axis_size(inter_axis))
    if sched is None:
        axis = _single_axis(axes, f"allreduce[{algorithm}]")
        sched = schedule_ir.build("allreduce", algorithm, axis_size(axis),
                                  segments=segments)
    return lower_allreduce(sched, x, axes, stage_impl=stage_impl,
                           stage_wire=stage_wire)


def lower_allreduce(sched: Schedule, x: jax.Array, axes: Axes, *,
                    stage_impl: Optional[str] = None,
                    stage_wire: Optional[str] = None,
                    wire: Optional[str] = None,
                    options: Optional[CollectiveOptions] = None
                    ) -> jax.Array:
    """Lower an allreduce schedule to explicit in-graph rounds.

    The schedule fixes algorithm and segmentation, so ``options=`` may
    only set the stage-tier knobs here.  ``wire=`` is the deprecated
    spelling of ``stage_wire=``.
    """
    stage_wire = renamed_kwarg("wire", wire, "stage_wire", stage_wire)
    stage_impl, stage_wire = CollectiveOptions.merge(
        options, stage_impl=stage_impl, stage_wire=stage_wire)
    if sched.name != "allreduce":
        raise ValueError(f"expected an allreduce schedule, got "
                         f"{sched.name!r}")
    _check_stage_opts(sched.algorithm, stage_impl, stage_wire)
    if sched.algorithm == "hierarchical":
        return _hierarchical_allreduce(sched, x, axes,
                                       stage_impl=stage_impl)
    axis = _single_axis(axes, f"allreduce[{sched.algorithm}]")
    _check_world(sched, axis)
    if sched.n == 1:
        return x
    if sched.algorithm == "ring":
        return _ring_allreduce(x, axis, sched.n, sched.segments,
                               stage_impl=stage_impl, wire=stage_wire)
    if sched.algorithm == "doubling":
        if sched.n & (sched.n - 1):
            # fold/unfold needs rank-asymmetric control flow, which SPMD
            # lowering cannot express — the fused node is the honest
            # equivalent (same dataflow position, XLA picks the rounds).
            return lax.psum(x, (axis,))
        return _butterfly_allreduce(x, axis, sched.n,
                                    stage_impl=stage_impl)
    raise ValueError(f"cannot lower algorithm {sched.algorithm!r}")


def _ring_allreduce(x: jax.Array, axis: str, n: int, segments: int,
                    stage_impl: Optional[str] = None,
                    wire: Optional[str] = None) -> jax.Array:
    """Ring allreduce as ``2(n-1)·S`` explicit ppermute rounds.

    Mirrors the host schedule chunk-for-chunk: reduce-scatter rounds send
    chunk ``(r-1-k) % n`` and combine into ``(r-2-k) % n``; allgather
    rounds forward chunk ``(r-k) % n``.  With ``segments=S > 1`` the
    per-segment chains carry no cross-segment dependencies, so XLA's
    scheduler overlaps segment ``k+1`` transport with segment ``k``
    combine — the pipelined schedule at Level B.

    With ``stage_impl`` the per-round combine runs as ONE fused kernel
    pass; with ``wire`` the transport additionally travels in the narrow
    dtype — int8 rounds ppermute the quantised chunk plus its scalar
    scale, and the allgather leg quantises each reduced chunk once at its
    owner and dequantises everywhere at the end (all ranks decode the
    same wire bytes, so results stay cross-rank bit-identical).
    """
    idx = lax.axis_index(axis)
    orig_shape, orig_dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    m = flat.shape[0]
    pieces = n * segments
    pad = (-m) % pieces
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    chunks = flat.reshape(n, segments, -1)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    for k in range(n - 1):              # reduce-scatter leg
        for s in range(segments):
            src_c = (idx - 1 - k) % n
            send = jnp.take(chunks[:, s], src_c, axis=0)
            tgt = (idx - 2 - k) % n
            if stage_impl is None:
                got = lax.ppermute(send, axis, fwd)
                chunks = chunks.at[tgt, s].add(got)
                continue
            gscale = None
            if wire == "int8":
                q, scale = kernel_ops.quantize_stage(send,
                                                     impl=stage_impl)
                send = q
                gscale = lax.ppermute(scale, axis, fwd)
            elif wire == "bf16":
                send = send.astype(jnp.bfloat16)
            got = lax.ppermute(send, axis, fwd)
            row = jnp.take(chunks[:, s], tgt, axis=0)
            new = kernel_ops.combine_stage(row, got, gscale,
                                           impl=stage_impl)
            chunks = chunks.at[tgt, s].set(new)
    if wire is None:
        for k in range(n - 1):          # allgather leg
            for s in range(segments):
                src_c = (idx - k) % n
                got = lax.ppermute(jnp.take(chunks[:, s], src_c, axis=0),
                                   axis, fwd)
                tgt = (idx - k - 1) % n
                chunks = chunks.at[tgt, s].set(got)
    else:
        # Allgather leg in wire dtype: each rank owns reduced chunk
        # ``idx`` after the RS leg — quantise it ONCE, forward the wire
        # payload (+ scale) around the ring, then dequantise every chunk
        # (own included) so all ranks decode identical wire bytes.
        wdt = _WIRE_DTYPES[wire]
        wchunks = jnp.zeros(chunks.shape, wdt)
        scales = jnp.zeros((n, segments), jnp.float32)
        for s in range(segments):
            own = jnp.take(chunks[:, s], idx, axis=0)
            if wire == "int8":
                q, scale = kernel_ops.quantize_stage(own, impl=stage_impl)
            else:
                q, scale = own.astype(wdt), jnp.float32(1.0)
            wchunks = wchunks.at[idx, s].set(q)
            scales = scales.at[idx, s].set(scale)
        for k in range(n - 1):
            for s in range(segments):
                src_c = (idx - k) % n
                got = lax.ppermute(jnp.take(wchunks[:, s], src_c, axis=0),
                                   axis, fwd)
                gscale = lax.ppermute(jnp.take(scales[:, s], src_c,
                                               axis=0), axis, fwd)
                tgt = (idx - k - 1) % n
                wchunks = wchunks.at[tgt, s].set(got)
                scales = scales.at[tgt, s].set(gscale)
        rows = []
        for i in range(n):
            segs = []
            for s in range(segments):
                segs.append(kernel_ops.combine_stage(
                    chunks[i, s], wchunks[i, s],
                    scales[i, s] if wire == "int8" else None,
                    accumulate=False, impl=stage_impl))
            rows.append(jnp.stack(segs))
        chunks = jnp.stack(rows)
    out = chunks.reshape(-1)
    if pad:
        out = out[:m]
    return out.reshape(orig_shape).astype(orig_dtype)


def _hierarchical_allreduce(sched: Schedule, x: jax.Array, axes: Axes,
                            stage_impl: Optional[str] = None
                            ) -> jax.Array:
    """Lower a :func:`repro.core.schedule.build_hierarchical` schedule
    over two mesh axes.

    Mirrors the schedule stage-for-stage: ``intra-1`` reduce-scatter
    ppermute rounds along the intra axis (send chunk ``(l-1-k) % n_i``,
    combine into ``(l-2-k) % n_i`` — identical indexing to the host
    programs), the inter allreduce of the owned chunk (butterfly rounds
    along the inter axis for power-of-two pod counts, else one fused
    ``lax.psum`` — the same trade the flat non-power-of-two doubling
    makes), and ``intra-1`` allgather rounds back.  Must run inside
    ``shard_map`` manual over both axes, passed in the schedule's
    major→minor ``(inter, intra)`` order.
    """
    inter_axis, intra_axis = _two_axes(axes)
    sizes = dict(sched.axes)
    n_e, n_i = sizes["inter"], sizes["intra"]
    if axis_size(inter_axis) != n_e or axis_size(intra_axis) != n_i:
        raise ValueError(
            f"schedule is for an (inter={n_e}) × (intra={n_i}) grid but "
            f"axes ({inter_axis!r}, {intra_axis!r}) have sizes "
            f"({axis_size(inter_axis)}, {axis_size(intra_axis)})")
    if n_e * n_i == 1:
        return x
    orig_shape, orig_dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    m = flat.shape[0]
    pad = (-m) % n_i
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    chunks = flat.reshape(n_i, -1)
    li = lax.axis_index(intra_axis)
    fwd = [(i, (i + 1) % n_i) for i in range(n_i)]
    for k in range(n_i - 1):            # stage 1: intra reduce-scatter
        got = lax.ppermute(jnp.take(chunks, (li - 1 - k) % n_i, axis=0),
                           intra_axis, fwd)
        tgt = (li - 2 - k) % n_i
        if stage_impl is None:
            chunks = chunks.at[tgt].add(got)
        else:
            row = jnp.take(chunks, tgt, axis=0)
            chunks = chunks.at[tgt].set(
                kernel_ops.combine_stage(row, got, impl=stage_impl))
    own = jnp.take(chunks, li % n_i, axis=0)
    if n_e > 1:                         # stage 2: inter allreduce
        if n_e & (n_e - 1):
            own = lax.psum(own, (inter_axis,))
        else:
            own = _butterfly_allreduce(own, inter_axis, n_e,
                                       stage_impl=stage_impl)
    chunks = chunks.at[li % n_i].set(own)
    for k in range(n_i - 1):            # stage 3: intra allgather
        got = lax.ppermute(jnp.take(chunks, (li - k) % n_i, axis=0),
                           intra_axis, fwd)
        chunks = chunks.at[(li - k - 1) % n_i].set(got)
    out = chunks.reshape(-1)
    if pad:
        out = out[:m]
    return out.reshape(orig_shape).astype(orig_dtype)


def _butterfly_allreduce(x: jax.Array, axis: str, n: int,
                         stage_impl: Optional[str] = None) -> jax.Array:
    """Recursive doubling as ``log2 n`` bidirectional ppermute rounds
    (power-of-two rank counts)."""
    acc = x
    mask = 1
    while mask < n:
        perm = [(i, i ^ mask) for i in range(n)]
        got = lax.ppermute(acc, axis, perm)
        if stage_impl is None:
            acc = acc + got
        else:
            acc = kernel_ops.combine_stage(acc, got, impl=stage_impl)
        mask <<= 1
    return acc


# ---------------------------------------------------------------------------
# Neighbourhood lowering
# ---------------------------------------------------------------------------
def lower_neighbor(sched: Schedule, sends: Dict[Any, jax.Array],
                   axis_name: str) -> Dict[Any, jax.Array]:
    """Lower a neighbourhood schedule to one ppermute per direction.

    ``sends[d]`` is this shard's outgoing payload toward direction ``d``
    (every shard passes the same dict — SPMD); the result maps each
    direction to the payload received *from* the neighbour in that
    direction.  The permutation pairs are read off the schedule's
    transfers, so non-periodic boundary ranks — which have no pair —
    receive ``ppermute``'s zeros: the halo zero-fill falls out of the
    schedule structure instead of explicit masking.
    """
    if sched.output_kind != "dirs":
        raise ValueError("lower_neighbor needs a neighbourhood schedule "
                         "(build_neighbor)")
    _check_world(sched, axis_name)
    by_dir: Dict[Any, list] = {}
    for t in sched.transfers():
        _, d = t.src_buf            # ("s", direction)
        by_dir.setdefault(d, []).append((t.src, t.dst))
    out: Dict[Any, jax.Array] = {}
    for d, payload in sends.items():
        pairs = sorted(by_dir.get(d, []))
        opp = (d[0], -d[1])
        if not pairs:               # degenerate grid: no such edge at all
            out[opp] = jnp.zeros_like(payload)
            continue
        out[opp] = lax.ppermute(payload, axis_name, pairs)
    return out


def chain_topology(n: int) -> Tuple[Tuple[Tuple[Tuple[int, int], int],
                                          ...], ...]:
    """1-D non-periodic chain topology (row decomposition), the shape
    :meth:`repro.core.tac.CartGroup.topology` produces for
    ``cart_create((n,))``."""
    topo = []
    for r in range(n):
        dirs = []
        if r > 0:
            dirs.append(((0, -1), r - 1))
        if r < n - 1:
            dirs.append(((0, 1), r + 1))
        topo.append(tuple(dirs))
    return tuple(topo)
