"""Level-B executor: lower schedule IR to in-graph XLA collectives.

The second consumer of :mod:`repro.core.schedule` (the first is the host
interpreter in :mod:`repro.core.collectives`): the SAME schedule object
that the host progress engine interprets op-by-op is mapped here onto
JAX primitives inside ``shard_map``-manual regions, where "task
dependencies" are HLO dataflow edges and "the scheduler" is XLA's
latency-hiding scheduler.

Three lowering strategies, chosen by the schedule:

* **Explicit rounds** — ring (any rank count, any segment count) and
  recursive-doubling (power-of-two rank counts) allreduces become
  ``lax.ppermute`` rounds whose count and order mirror the schedule's
  transfer structure exactly (``2(n-1)·S`` ring rounds, ``log2 n``
  butterfly rounds; asserted against ``Schedule`` op counts in tests).
  Segmented schedules emit independent per-segment round chains with no
  artificial dependencies between them, so XLA overlaps the combine of
  segment *k* with the transport of segment *k+1* — the in-graph
  realisation of the pipelined schedule.

* **Fused node** — ``algorithm="native"`` lowers the whole allreduce to
  one ``lax.psum``; XLA's own combiner picks the wire schedule.  This is
  what :func:`repro.core.overlap.sync_grads` uses by default, which keeps
  the bucketed/sentinel HLO (one ``all-reduce`` per bucket, same order)
  byte-compatible with the pre-IR code.

* **Neighbourhood** — a :func:`repro.core.schedule.build_neighbor`
  schedule lowers to one ``ppermute`` per direction whose permutation
  pairs are read straight off the schedule's transfers; ranks missing a
  direction (non-periodic boundaries) simply have no pair and XLA
  delivers zeros — which is how
  :func:`repro.core.overlap.halo_exchange_rows` gets its zero boundary
  halos without explicit masking.

* **Hierarchical two-axis** — a
  :func:`repro.core.schedule.build_hierarchical` schedule lowers over TWO
  mesh axes ``(inter, intra)``: the intra-axis ring reduce-scatter and
  allgather stages become explicit ``ppermute`` rounds along the intra
  axis, and the inter stage becomes the recursive-doubling butterfly
  along the inter axis (power-of-two pod counts) or one fused
  ``lax.psum`` of the owned chunk (any pod count) — the same three-stage
  composition the host interpreter runs, reading its structure off the
  schedule's ``axes`` metadata.

In-graph lowering restrictions (by construction of the substrate): the
combining operator is addition (the gradient/residual case), payloads are
dense arrays, and flat explicit-round lowerings run over ONE mesh axis —
``native`` takes an axis tuple, and ``hierarchical`` takes exactly two
axes in ``(inter, intra)`` order.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size
from . import schedule as schedule_ir
from .schedule import Schedule, Send

Axes = Union[str, Sequence[str]]


def _single_axis(axis_name: Axes, what: str) -> str:
    if isinstance(axis_name, str):
        return axis_name
    axes = tuple(axis_name)
    if len(axes) != 1:
        raise ValueError(f"{what} lowers over a single mesh axis, got "
                         f"{axes}; use algorithm='native' for axis tuples")
    return axes[0]


def _two_axes(axis_name: Axes) -> Tuple[str, str]:
    """``(inter, intra)`` mesh axes of a hierarchical lowering."""
    axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    if len(axes) != 2:
        raise ValueError(f"hierarchical allreduce lowers over exactly two "
                         f"mesh axes (inter, intra), got {axes}")
    return axes[0], axes[1]


def _check_world(sched: Schedule, axis_name: str) -> None:
    n = axis_size(axis_name)
    if sched.n != n:
        raise ValueError(f"schedule is for {sched.n} ranks but axis "
                         f"{axis_name!r} has {n} shards")


def sends_per_rank(sched: Schedule) -> int:
    """Transfer rounds each rank issues — the lowered ppermute count per
    explicit-round leg (structural-equivalence hook for tests)."""
    return max(sum(isinstance(op, Send) for op in prog)
               for prog in sched.programs)


# ---------------------------------------------------------------------------
# Allreduce lowerings
# ---------------------------------------------------------------------------
def allreduce(x: jax.Array, axes: Axes, *,
              algorithm: str = "native", segments: int = 1,
              sched: Optional[Schedule] = None) -> jax.Array:
    """Sum-allreduce ``x`` over ``axes`` with a chosen schedule.

    ``algorithm="native"`` emits one fused ``lax.psum`` node (XLA picks
    the rounds); ``"ring"``/``"doubling"`` build (or take) a schedule and
    emit its explicit ppermute rounds.  Must be called inside
    ``shard_map`` manual over ``axes``.
    """
    if sched is None and algorithm == "native":
        return lax.psum(x, tuple(axes) if not isinstance(axes, str)
                        else (axes,))
    if sched is None and algorithm == "hierarchical":
        if segments != 1:
            # mirror Collectives._resolve: the composed schedule is fixed,
            # silently dropping segments would fake pipelining.
            raise ValueError("hierarchical allreduce fixes the composed "
                             "schedule; drop segments=")
        inter_axis, intra_axis = _two_axes(axes)
        sched = schedule_ir.build_hierarchical(axis_size(intra_axis),
                                               axis_size(inter_axis))
    if sched is None:
        axis = _single_axis(axes, f"allreduce[{algorithm}]")
        sched = schedule_ir.build("allreduce", algorithm, axis_size(axis),
                                  segments=segments)
    return lower_allreduce(sched, x, axes)


def lower_allreduce(sched: Schedule, x: jax.Array,
                    axes: Axes) -> jax.Array:
    """Lower an allreduce schedule to explicit in-graph rounds."""
    if sched.name != "allreduce":
        raise ValueError(f"expected an allreduce schedule, got "
                         f"{sched.name!r}")
    if sched.algorithm == "hierarchical":
        return _hierarchical_allreduce(sched, x, axes)
    axis = _single_axis(axes, f"allreduce[{sched.algorithm}]")
    _check_world(sched, axis)
    if sched.n == 1:
        return x
    if sched.algorithm == "ring":
        return _ring_allreduce(x, axis, sched.n, sched.segments)
    if sched.algorithm == "doubling":
        if sched.n & (sched.n - 1):
            # fold/unfold needs rank-asymmetric control flow, which SPMD
            # lowering cannot express — the fused node is the honest
            # equivalent (same dataflow position, XLA picks the rounds).
            return lax.psum(x, (axis,))
        return _butterfly_allreduce(x, axis, sched.n)
    raise ValueError(f"cannot lower algorithm {sched.algorithm!r}")


def _ring_allreduce(x: jax.Array, axis: str, n: int,
                    segments: int) -> jax.Array:
    """Ring allreduce as ``2(n-1)·S`` explicit ppermute rounds.

    Mirrors the host schedule chunk-for-chunk: reduce-scatter rounds send
    chunk ``(r-1-k) % n`` and combine into ``(r-2-k) % n``; allgather
    rounds forward chunk ``(r-k) % n``.  With ``segments=S > 1`` the
    per-segment chains carry no cross-segment dependencies, so XLA's
    scheduler overlaps segment ``k+1`` transport with segment ``k``
    combine — the pipelined schedule at Level B.
    """
    idx = lax.axis_index(axis)
    orig_shape, orig_dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    m = flat.shape[0]
    pieces = n * segments
    pad = (-m) % pieces
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    chunks = flat.reshape(n, segments, -1)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    for k in range(n - 1):              # reduce-scatter leg
        for s in range(segments):
            src_c = (idx - 1 - k) % n
            got = lax.ppermute(jnp.take(chunks[:, s], src_c, axis=0),
                               axis, fwd)
            tgt = (idx - 2 - k) % n
            chunks = chunks.at[tgt, s].add(got)
    for k in range(n - 1):              # allgather leg
        for s in range(segments):
            src_c = (idx - k) % n
            got = lax.ppermute(jnp.take(chunks[:, s], src_c, axis=0),
                               axis, fwd)
            tgt = (idx - k - 1) % n
            chunks = chunks.at[tgt, s].set(got)
    out = chunks.reshape(-1)
    if pad:
        out = out[:m]
    return out.reshape(orig_shape).astype(orig_dtype)


def _hierarchical_allreduce(sched: Schedule, x: jax.Array,
                            axes: Axes) -> jax.Array:
    """Lower a :func:`repro.core.schedule.build_hierarchical` schedule
    over two mesh axes.

    Mirrors the schedule stage-for-stage: ``intra-1`` reduce-scatter
    ppermute rounds along the intra axis (send chunk ``(l-1-k) % n_i``,
    combine into ``(l-2-k) % n_i`` — identical indexing to the host
    programs), the inter allreduce of the owned chunk (butterfly rounds
    along the inter axis for power-of-two pod counts, else one fused
    ``lax.psum`` — the same trade the flat non-power-of-two doubling
    makes), and ``intra-1`` allgather rounds back.  Must run inside
    ``shard_map`` manual over both axes, passed in the schedule's
    major→minor ``(inter, intra)`` order.
    """
    inter_axis, intra_axis = _two_axes(axes)
    sizes = dict(sched.axes)
    n_e, n_i = sizes["inter"], sizes["intra"]
    if axis_size(inter_axis) != n_e or axis_size(intra_axis) != n_i:
        raise ValueError(
            f"schedule is for an (inter={n_e}) × (intra={n_i}) grid but "
            f"axes ({inter_axis!r}, {intra_axis!r}) have sizes "
            f"({axis_size(inter_axis)}, {axis_size(intra_axis)})")
    if n_e * n_i == 1:
        return x
    orig_shape, orig_dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    m = flat.shape[0]
    pad = (-m) % n_i
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    chunks = flat.reshape(n_i, -1)
    li = lax.axis_index(intra_axis)
    fwd = [(i, (i + 1) % n_i) for i in range(n_i)]
    for k in range(n_i - 1):            # stage 1: intra reduce-scatter
        got = lax.ppermute(jnp.take(chunks, (li - 1 - k) % n_i, axis=0),
                           intra_axis, fwd)
        chunks = chunks.at[(li - 2 - k) % n_i].add(got)
    own = jnp.take(chunks, li % n_i, axis=0)
    if n_e > 1:                         # stage 2: inter allreduce
        if n_e & (n_e - 1):
            own = lax.psum(own, (inter_axis,))
        else:
            own = _butterfly_allreduce(own, inter_axis, n_e)
    chunks = chunks.at[li % n_i].set(own)
    for k in range(n_i - 1):            # stage 3: intra allgather
        got = lax.ppermute(jnp.take(chunks, (li - k) % n_i, axis=0),
                           intra_axis, fwd)
        chunks = chunks.at[(li - k - 1) % n_i].set(got)
    out = chunks.reshape(-1)
    if pad:
        out = out[:m]
    return out.reshape(orig_shape).astype(orig_dtype)


def _butterfly_allreduce(x: jax.Array, axis: str, n: int) -> jax.Array:
    """Recursive doubling as ``log2 n`` bidirectional ppermute rounds
    (power-of-two rank counts)."""
    acc = x
    mask = 1
    while mask < n:
        perm = [(i, i ^ mask) for i in range(n)]
        acc = acc + lax.ppermute(acc, axis, perm)
        mask <<= 1
    return acc


# ---------------------------------------------------------------------------
# Neighbourhood lowering
# ---------------------------------------------------------------------------
def lower_neighbor(sched: Schedule, sends: Dict[Any, jax.Array],
                   axis_name: str) -> Dict[Any, jax.Array]:
    """Lower a neighbourhood schedule to one ppermute per direction.

    ``sends[d]`` is this shard's outgoing payload toward direction ``d``
    (every shard passes the same dict — SPMD); the result maps each
    direction to the payload received *from* the neighbour in that
    direction.  The permutation pairs are read off the schedule's
    transfers, so non-periodic boundary ranks — which have no pair —
    receive ``ppermute``'s zeros: the halo zero-fill falls out of the
    schedule structure instead of explicit masking.
    """
    if sched.output_kind != "dirs":
        raise ValueError("lower_neighbor needs a neighbourhood schedule "
                         "(build_neighbor)")
    _check_world(sched, axis_name)
    by_dir: Dict[Any, list] = {}
    for t in sched.transfers():
        _, d = t.src_buf            # ("s", direction)
        by_dir.setdefault(d, []).append((t.src, t.dst))
    out: Dict[Any, jax.Array] = {}
    for d, payload in sends.items():
        pairs = sorted(by_dir.get(d, []))
        opp = (d[0], -d[1])
        if not pairs:               # degenerate grid: no such edge at all
            out[opp] = jnp.zeros_like(payload)
            continue
        out[opp] = lax.ppermute(payload, axis_name, pairs)
    return out


def chain_topology(n: int) -> Tuple[Tuple[Tuple[Tuple[int, int], int],
                                          ...], ...]:
    """1-D non-periodic chain topology (row decomposition), the shape
    :meth:`repro.core.tac.CartGroup.topology` produces for
    ``cart_create((n,))``."""
    topo = []
    for r in range(n):
        dirs = []
        if r > 0:
            dirs.append(((0, -1), r - 1))
        if r < n - 1:
            dirs.append(((0, 1), r + 1))
        topo.append(tuple(dirs))
    return tuple(topo)
