"""Tasks and data-flow dependency tracking (OmpSs-2 style, paper §2.1).

Tasks declare *data regions* they read (``in_``), write (``out``) or update
(``inout``).  Submission order plus the declared accesses induce the
dependency graph, with the usual serialisation semantics:

* a reader depends on the last writer of each region it reads;
* a writer depends on the last writer **and** on every reader registered
  since that writer (anti-dependency);
* ``inout`` behaves as read+write.

A task *releases* its dependencies when its event counter reaches zero
(paper §4.6): that is, when the task body has finished **and** every bound
external event has been fulfilled.  Successors whose predecessor count drops
to zero become ready.  This is precisely the mechanism TAMPI's non-blocking
mode builds on: a communication task can finish executing while its
dependency release is deferred to the completion of the MPI requests it
initiated (§6.2).
"""

from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Callable, Dict, Hashable, Iterable, List, Optional
from typing import Sequence, Set, Tuple, TYPE_CHECKING

from .events import EventCounter

if TYPE_CHECKING:  # pragma: no cover
    from .events import BlockingContext
    from .executor import TaskRuntime

_task_ids = itertools.count()

# -- Task states --------------------------------------------------------------
CREATED = "created"      # submitted, waiting on predecessors
READY = "ready"          # in the ready queue
RUNNING = "running"      # body executing on a worker
BLOCKED = "blocked"      # paused inside block_current_task
FINISHED = "finished"    # body returned; external events may be pending
RELEASED = "released"    # event counter hit zero; dependencies released


class Task:
    """A unit of work with data-flow dependencies.

    Not instantiated directly — use :meth:`TaskRuntime.task` /
    :meth:`TaskRuntime.submit`.
    """

    def __init__(self, fn: Callable[..., Any], args: Tuple[Any, ...],
                 kwargs: Dict[str, Any], *, name: Optional[str],
                 runtime: "TaskRuntime", cost: float = 1.0,
                 idempotent: bool = False, label: Optional[str] = None,
                 rank: Optional[int] = None):
        self.id = next(_task_ids)
        self.fn = fn
        self.args = args
        self.kwargs = kwargs
        self.name = name or getattr(fn, "__name__", f"task{self.id}")
        self.label = label  # free-form grouping tag (used by benchmarks)
        self.rank = rank    # logical rank for trace attribution (repro.obs)
        self.cost = cost    # abstract cost for the makespan simulator
        self.idempotent = idempotent  # eligible for speculative re-execution
        self.result: Any = None
        self.error: Optional[BaseException] = None

        self._runtime = runtime
        self._state = CREATED
        self._state_lock = threading.Lock()
        self._num_pending = 0          # unreleased predecessors
        self._successors: List["Task"] = []
        self._predecessors: List["Task"] = []   # kept for introspection/sim
        self._event_counter = EventCounter(self, runtime)
        self._blocking_context: Optional["BlockingContext"] = None
        self._completed_once = False   # guards duplicate (speculative) runs
        self._started_at: Optional[float] = None
        self._finished_at: Optional[float] = None
        # Filled in by the graph at submission time:
        self.accesses: Dict[str, Tuple[Hashable, ...]] = {}

    # -- introspection ---------------------------------------------------
    @property
    def state(self) -> str:
        return self._state

    @property
    def successors(self) -> Tuple["Task", ...]:
        return tuple(self._successors)

    @property
    def predecessors(self) -> Tuple["Task", ...]:
        return tuple(self._predecessors)

    @property
    def pending_events(self) -> int:
        return self._event_counter.value

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<Task #{self.id} {self.name!r} {self._state}>"


def _region_key(obj: Any) -> Hashable:
    """Normalise a user-provided data region into a dictionary key.

    Strings/ints/tuples are value-keyed; arbitrary objects are identity-keyed
    (the region table holds a reference so ids cannot be recycled while the
    region is live).
    """
    if isinstance(obj, (str, bytes, int, tuple, frozenset)):
        return ("val", obj)
    return ("obj", id(obj))


class _RegionState:
    __slots__ = ("anchor", "last_writer", "readers")

    def __init__(self, anchor: Any) -> None:
        self.anchor = anchor  # keep the object alive (identity-keyed regions)
        self.last_writer: Optional[Task] = None
        self.readers: List[Task] = []


class TaskGraph:
    """Registers tasks in submission order and wires their dependencies.

    Thread-safe; shared with the executor which drives state transitions.
    """

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._regions: Dict[Hashable, _RegionState] = {}
        self._tasks: List[Task] = []

    @property
    def tasks(self) -> List[Task]:
        with self._lock:
            return list(self._tasks)

    def register(self, task: Task, in_: Sequence[Any], out: Sequence[Any],
                 inout: Sequence[Any]) -> bool:
        """Wire ``task`` into the graph. Returns True if immediately ready."""
        reads = tuple(in_) + tuple(inout)
        writes = tuple(out) + tuple(inout)
        task.accesses = {
            "in": tuple(_region_key(r) for r in in_),
            "out": tuple(_region_key(r) for r in out),
            "inout": tuple(_region_key(r) for r in inout),
        }
        preds: Set[Task] = set()
        with self._lock:
            self._tasks.append(task)
            for r in reads:
                st = self._region(r)
                if st.last_writer is not None and not _is_released(st.last_writer):
                    preds.add(st.last_writer)
            for r in writes:
                st = self._region(r)
                if st.last_writer is not None and not _is_released(st.last_writer):
                    preds.add(st.last_writer)
                for reader in st.readers:
                    if reader is not task and not _is_released(reader):
                        preds.add(reader)
            # Second pass: update region tables to reflect this task's
            # accesses (readers accumulate; a write resets the epoch).
            for r in writes:
                st = self._region(r)
                st.last_writer = task
                st.readers = []
            for r in reads:
                # inout regions were reset above; record the read so a later
                # writer anti-depends on us.
                self._region(r).readers.append(task)
            preds.discard(task)
            task._num_pending = len(preds)
            task._predecessors = sorted(preds, key=lambda t: t.id)
            for p in preds:
                p._successors.append(task)
            return task._num_pending == 0

    def on_release(self, task: Task) -> List[Task]:
        """Called by the runtime when ``task`` releases its dependencies.

        Returns the successors that became ready.
        """
        newly_ready: List[Task] = []
        with self._lock:
            for s in task._successors:
                s._num_pending -= 1
                if s._num_pending == 0:
                    newly_ready.append(s)
        return newly_ready

    def _region(self, r: Any) -> _RegionState:
        key = _region_key(r)
        st = self._regions.get(key)
        if st is None:
            st = _RegionState(r)
            self._regions[key] = st
        return st

    # -- analytics (used by the makespan simulator & benchmarks) ---------
    def critical_path(self) -> float:
        """Length (sum of ``cost``) of the longest dependency chain."""
        with self._lock:
            order = list(self._tasks)
        dist: Dict[int, float] = {}
        for t in order:  # submission order is a topological order
            base = max((dist[p.id] for p in t._predecessors), default=0.0)
            dist[t.id] = base + t.cost
        return max(dist.values(), default=0.0)

    def edges(self) -> List[Tuple[int, int]]:
        with self._lock:
            return [(p.id, s.id) for p in self._tasks for s in p._successors]


def _is_released(task: Task) -> bool:
    return task._state == RELEASED
