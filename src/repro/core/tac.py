"""TAC — Task-Aware Collectives: the TAMPI analogue for JAX (paper §6).

TAMPI intercepts MPI calls and re-expresses them against the pause/resume and
external-events APIs.  In JAX the "MPI layer" is the asynchronous dispatch
machinery: every ``jax.Array`` is a future (``.is_ready()`` is the
non-blocking completion test, ``jax.block_until_ready`` the blocking wait),
``jax.device_put`` is an asynchronous transfer, and host-side channels give
point-to-point semantics between logical ranks.  TAC wraps those operations
in the two modes the paper defines:

* **Blocking mode** (§6.1, Fig. 3): ``tac.wait(handle)`` from inside a task
  converts a blocking wait into *test → register ticket → pause task*; a
  polling service tests the pending tickets and unblocks tasks on
  completion.  The hardware thread never blocks inside the "MPI library".

* **Non-blocking mode** (§6.2, Fig. 4): ``tac.iwait(handle)`` /
  ``tac.iwaitall(handles)`` bind the handles to the calling task's event
  counter and return immediately.  The task may finish; its dependencies are
  released only when the bound operations complete.  No context switch, no
  live stack, no extra scheduler round trips.

Both modes are enabled by initialising TAC with the ``TASK_MULTIPLE``
threading level (§6.3).  Without it, the wrappers fall back to the plain
blocking wait — the "PMPI" path of Fig. 3/4 — and programs must serialise
communication tasks themselves (the *sentinel* pattern, §7.1).
"""

from __future__ import annotations

import concurrent.futures
import itertools
import math
import threading
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax

from ..obs import trace as _tr
from ..obs.registry import REGISTRY as _REGISTRY
from .continuations import PushCompletion
from .events import (current_task, get_current_blocking_context,
                     get_current_event_counter,
                     increase_current_task_event_counter,
                     decrease_task_event_counter, block_current_task,
                     unblock_task, BlockingContext, EventCounter)
from .executor import TaskRuntime

# -- threading levels (§6.3) -------------------------------------------------
THREAD_SINGLE = 0
THREAD_FUNNELED = 1
THREAD_SERIALIZED = 2
THREAD_MULTIPLE = 3
TASK_MULTIPLE = 4  # monotonically greater than THREAD_MULTIPLE (§6.3)

_provided_level = THREAD_MULTIPLE
_level_lock = threading.Lock()


def init(required: int = TASK_MULTIPLE) -> int:
    """Initialise TAC, requesting a threading level (cf. MPI_Init_thread).

    Returns the *provided* level.  ``TASK_MULTIPLE`` is always available in
    this runtime; programs may still request less to emulate legacy MPI
    libraries (the benchmarks use this to build the Sentinel versions).
    """
    global _provided_level
    with _level_lock:
        _provided_level = min(required, TASK_MULTIPLE)
        return _provided_level


def query_thread() -> int:
    return _provided_level


def is_enabled() -> bool:
    """True when the TASK_MULTIPLE interoperability mechanism is active."""
    return _provided_level >= TASK_MULTIPLE


# ---------------------------------------------------------------------------
# Failure model (ULFM: MPI_ERR_PROC_FAILED / MPI_ERR_REVOKED)
# ---------------------------------------------------------------------------
class RankFailedError(RuntimeError):
    """A peer involved in this operation is dead (MPI_ERR_PROC_FAILED).

    Raised from ``handle.result`` (and therefore from :func:`wait`, from a
    continuation's reader, and from a collective's consumer) — never from
    the posting call itself, matching ULFM's error-on-completion model.
    ``rank`` is the failed world rank when known.
    """

    def __init__(self, rank: Optional[int] = None,
                 message: Optional[str] = None) -> None:
        self.rank = rank
        if message is None:
            message = (f"rank {rank} failed" if rank is not None
                       else "a peer rank failed")
        super().__init__(message)


class CommRevokedError(RankFailedError):
    """The communicator was revoked (MPI_ERR_REVOKED).

    A subclass of :class:`RankFailedError` so recovery code that catches
    the failure also catches the revocation that propagates it.  After a
    revoke, every pending and future operation on the communicator fails
    with this error until the survivors complete a :meth:`CommWorld.shrink`
    agreement.
    """

    def __init__(self, rank: Optional[int] = None,
                 message: Optional[str] = None) -> None:
        super().__init__(rank, message or "communicator revoked")


# ---------------------------------------------------------------------------
# Asynchronous handles ("MPI_Request" analogues)
# ---------------------------------------------------------------------------
class AsyncHandle:
    """A testable/waitable in-flight operation — THE async protocol.

    This is the one contract every asynchronous surface in the runtime
    speaks (documented here once; ``docs/api.md`` lists the conforming
    types):

    * ``test() -> bool`` — non-blocking completion probe (``MPI_Test``);
    * ``wait() -> Any``  — OS-level blocking wait (the 'PMPI' path),
      returning the result;
    * ``result``         — the completed operation's value; raises the
      stored error for erroneous completions (ULFM's
      error-on-completion model).

    Everything that consumes handles — :func:`wait`/:func:`iwait`/
    :func:`iwaitall`/:func:`waitall`,
    :meth:`repro.core.executor.TaskRuntime.taskwait`,
    :meth:`repro.core.continuations.ContinuationEngine.attach`, and the
    serving engine (:mod:`repro.serving`) — accepts exactly this
    protocol (loose inputs are coerced by :func:`as_handle`), and
    everything that produces asynchrony — :class:`ArrayHandle`,
    :class:`EventHandle` (and its send/recv/collective subclasses),
    :class:`FutureHandle`, :class:`CompositeHandle`,
    :class:`~repro.core.continuations.Continuation` — returns it.
    Push-capable handles additionally expose ``on_complete(cb)``
    (:class:`~repro.core.continuations.PushCompletion`), which the
    continuation engine uses for O(completions) notification; handles
    without it are re-tested from the engine's fallback poll list.
    """

    def test(self) -> bool:
        raise NotImplementedError

    def wait(self) -> Any:
        """OS-level blocking wait (the 'PMPI' path). Returns the result."""
        raise NotImplementedError

    @property
    def result(self) -> Any:
        return getattr(self, "_result", None)


class ArrayHandle(AsyncHandle):
    """Completion of asynchronously dispatched JAX arrays.

    ``jax.Array.is_ready()`` is the non-blocking completion test — the exact
    analogue of ``MPI_Test`` for XLA's async dispatch.
    """

    def __init__(self, value: Any) -> None:
        self._result = value
        self._leaves = [x for x in jax.tree_util.tree_leaves(value)
                        if hasattr(x, "is_ready")]

    def test(self) -> bool:
        return all(x.is_ready() for x in self._leaves)

    def wait(self) -> Any:
        jax.block_until_ready(self._result)
        return self._result


class EventHandle(PushCompletion, AsyncHandle):
    """A manually completed handle (asynchronous host work, I/O, ...).

    Supports **push** completion notification
    (:class:`repro.core.continuations.PushCompletion`):
    :meth:`~repro.core.continuations.PushCompletion.on_complete`
    registers a callback that :meth:`complete` invokes at match time —
    the hook the continuation engine uses to make progress
    O(completions) instead of O(in-flight ops) per poll.  ``complete``
    is idempotent (the first completion wins and fires the callbacks
    exactly once) — a buffered send may be locally complete before its
    match confirms it.

    A handle may also complete *erroneously* via :meth:`fail` — the ULFM
    failure path: the handle is done (``test()`` is True, callbacks fire,
    waiters wake) but ``result`` re-raises the stored exception on every
    consumer.  That is what lets a dead peer surface as a
    :class:`RankFailedError` at task granularity instead of a hung
    ``taskwait``: the failure rides the exact same push-notification
    plumbing as success.
    """

    def __init__(self) -> None:
        super().__init__()
        self._result: Any = None
        self.error: Optional[BaseException] = None
        if _tr.TRACING:
            # Handle-lifecycle tracing: the in-flight span opens here
            # (post time) and closes on complete/fail.  The posting
            # task's rank attributes the span (per-rank overlap
            # accounting); outside task code the span stays unattributed.
            self._t_post = time.monotonic()
            task = current_task()
            self._obs_rank = None if task is None else task.rank
            _REGISTRY.gauge("tac.inflight_handles").inc()

    def _trace_done(self) -> None:
        """Close the in-flight span (first completion only)."""
        t_post = getattr(self, "_t_post", None)
        if t_post is None:
            return
        _REGISTRY.gauge("tac.inflight_handles").dec()
        _tr.TRACER.span("handle", "inflight", t_post, time.monotonic(),
                        rank=self._obs_rank, kind=type(self).__name__,
                        ok=self.error is None)

    @property
    def result(self) -> Any:
        if self.error is not None:
            raise self.error
        return self._result

    def fail(self, exc: BaseException) -> None:
        """Complete erroneously: consumers of ``result`` re-raise ``exc``.

        Idempotent like :meth:`complete`, and a no-op on an
        already-successful handle (the first completion wins — a message
        delivered before the failure was detected stays delivered).
        """
        with self._cb_lock:
            if self._done:
                return
            self.error = exc
            self._done = True
            if self._waiter is not None:
                self._waiter.set()
            cbs, self._cbs = self._cbs, []
        if _tr.TRACING:
            self._trace_done()
        for cb in cbs:
            cb(self)

    def complete(self, result: Any = None) -> None:
        # Open-coded _complete_once(assign): this runs 2-3 times per
        # transfer (O(n²) per allreduce) and the closure-pair allocation
        # is measurable there.  Semantics are identical.
        with self._cb_lock:
            if self._done:
                return
            self._result = result
            self._done = True
            if self._waiter is not None:
                self._waiter.set()
            cbs, self._cbs = self._cbs, []
        if _tr.TRACING:
            self._trace_done()
        for cb in cbs:
            cb(self)

    def wait(self) -> Any:
        self._wait_event().wait()
        return self.result


class FutureHandle(AsyncHandle):
    """Adapter for ``concurrent.futures.Future``."""

    def __init__(self, future: Any) -> None:
        self._future = future

    def test(self) -> bool:
        return self._future.done()

    def wait(self) -> Any:
        return self._future.result()

    def on_complete(self, cb: Callable[["FutureHandle"], None]) -> None:
        """Push notification via ``Future.add_done_callback``."""
        self._future.add_done_callback(lambda _f: cb(self))

    @property
    def result(self) -> Any:
        return self._future.result() if self._future.done() else None


class CompositeHandle(AsyncHandle):
    def __init__(self, handles: Sequence[AsyncHandle]) -> None:
        self._handles = list(handles)

    def test(self) -> bool:
        return all(h.test() for h in self._handles)

    def wait(self) -> Any:
        return [h.wait() for h in self._handles]

    @property
    def result(self) -> Any:
        return [h.result for h in self._handles]


def run_async(fn: Callable[..., Any], *args: Any, **kwargs: Any) -> ArrayHandle:
    """Dispatch a (jitted) computation and return its completion handle.

    JAX dispatch is asynchronous, so this returns as soon as the work is
    enqueued on the device — the handle completes when the result arrays are
    materialised.
    """
    return ArrayHandle(fn(*args, **kwargs))


def transfer(value: Any, target: Any) -> ArrayHandle:
    """Asynchronous device transfer (the point-to-point data motion)."""
    return ArrayHandle(jax.device_put(value, target))


# ---------------------------------------------------------------------------
# CommWorld: logical ranks with MPI point-to-point semantics
# ---------------------------------------------------------------------------
class _SendHandle(EventHandle):
    def __init__(self, payload: Any, synchronous: bool) -> None:
        super().__init__()
        self.payload = payload
        if not synchronous:
            # Buffered send: locally complete immediately (MPI_Isend on a
            # small message); synchronous send completes on match (MPI_Issend).
            # No other thread can hold a reference during __init__, so the
            # completion publishes lock-free (complete() stays idempotent:
            # the match-time re-complete sees _done and returns).
            self._result = payload
            self._done = True
            if _tr.TRACING:
                # complete() will early-return on the match-time call, so
                # close the (zero-length) in-flight span here.
                self._trace_done()


class _RecvHandle(EventHandle):
    pass


class CommWorld:
    """``size`` logical ranks with ordered, tagged point-to-point messaging.

    Matching follows MPI semantics: messages between the same (src, dst, tag)
    triple are non-overtaking; matching is eager (performed at post time
    under the world lock).  Payloads are passed by reference — callers
    sharing device arrays get zero-copy semantics on a single host, which is
    the honest analogue of intra-node MPI.
    """

    def __init__(self, size: int) -> None:
        self.size = size
        self._lock = threading.Lock()
        self._msgs: dict = {}   # (src, dst, tag) -> list[_SendHandle]
        self._recvs: dict = {}  # (src, dst, tag) -> list[_RecvHandle]
        self.stats = {"messages": 0, "bytes": 0}
        self._group_seq = itertools.count()   # communicator context ids
        self._split_calls = [0] * size        # per-rank split generation
        self._splits: Dict[int, dict] = {}    # generation -> rank -> call
        # -- failure model (ULFM) -------------------------------------------
        self.epoch = 0                        # bumped on fail/revoke/shrink
        self._failed: set = set()             # dead world ranks
        self._revoked = False                 # whole-world revoke in effect
        self._shrink_calls = [0] * size       # per-rank shrink generation
        self._shrinks: Dict[int, dict] = {}   # generation -> rank -> handle
        self._fault_hook: Optional[Callable] = None   # FaultInjector tap

    def _key(self, src: int, dst: int, tag: Any) -> Tuple[int, int, Any]:
        return (src, dst, tag)

    def _failed_op(self, handle: EventHandle, src: int,
                   dst: int) -> EventHandle:
        """Fail a fresh handle for an op that can never complete."""
        if self._revoked:
            handle.fail(CommRevokedError())
        else:
            if src in self._failed:
                dead: Optional[int] = src
            elif dst in self._failed:
                dead = dst
            else:
                dead = next(iter(self._failed), None)
            handle.fail(RankFailedError(dead))
        return handle

    def isend(self, payload: Any, *, src: int, dst: int, tag: Any = 0,
              synchronous: bool = False) -> _SendHandle:
        if not (0 <= src < self.size and 0 <= dst < self.size):
            raise ValueError(f"rank out of range: {src}->{dst}")
        hook = self._fault_hook
        if hook is not None:
            hook("isend", src, dst, tag)
        if self._revoked or src in self._failed or dst in self._failed:
            # ULFM: an op naming a dead peer (or posted on a revoked
            # communicator) completes erroneously instead of matching.
            return self._failed_op(_SendHandle(payload, True), src, dst)
        h = _SendHandle(payload, synchronous)
        key = self._key(src, dst, tag)
        matched = None
        with self._lock:
            self.stats["messages"] += 1
            recvs = self._recvs.get(key)
            if recvs:
                matched = recvs.pop(0)
            else:
                self._msgs.setdefault(key, []).append(h)
        if matched is not None:
            if _tr.TRACING:
                _tr.TRACER.instant("handle", "match", src=src, dst=dst,
                                   rank=getattr(h, "_obs_rank", None))
            # Complete OUTSIDE the world lock: completion may push a
            # continuation whose dispatch posts messages (needs the lock).
            matched.complete(payload)
            if not h._done:                 # buffered sends already are
                h.complete(payload)
        return h

    def irecv(self, *, src: int, dst: int, tag: Any = 0) -> _RecvHandle:
        hook = self._fault_hook
        if hook is not None:
            hook("irecv", src, dst, tag)
        if self._revoked or src in self._failed or dst in self._failed:
            return self._failed_op(_RecvHandle(), src, dst)
        key = self._key(src, dst, tag)
        r = _RecvHandle()
        matched = None
        with self._lock:
            msgs = self._msgs.get(key)
            if msgs:
                matched = msgs.pop(0)
            else:
                self._recvs.setdefault(key, []).append(r)
        if matched is not None:
            if _tr.TRACING:
                _tr.TRACER.instant("handle", "match", src=src, dst=dst,
                                   rank=getattr(r, "_obs_rank", None))
            if not matched._done:           # synchronous send: confirm match
                matched.complete(matched.payload)   # outside the lock
            r.complete(matched.payload)
        return r

    # Blocking conveniences (intercepted like MPI_Recv/MPI_Ssend, Fig. 3).
    def recv(self, *, src: int, dst: int, tag: Any = 0) -> Any:
        return wait(self.irecv(src=src, dst=dst, tag=tag))

    def send(self, payload: Any, *, src: int, dst: int, tag: Any = 0) -> None:
        wait(self.isend(payload, src=src, dst=dst, tag=tag))

    def ssend(self, payload: Any, *, src: int, dst: int, tag: Any = 0) -> None:
        wait(self.isend(payload, src=src, dst=dst, tag=tag, synchronous=True))

    # -- rank-translation hooks ---------------------------------------------
    # A CommWorld is its own trivial "group": these identity hooks let
    # schedule-IR consumers (the host interpreter, the lowering, the
    # hierarchical composition) translate communicator-local ranks
    # uniformly without testing for CommGroup.
    def world_rank(self, rank: int) -> int:
        """Communicator-local rank -> world rank (identity for the world)."""
        if not 0 <= rank < self.size:
            raise ValueError(f"rank {rank} out of range for size {self.size}")
        return rank

    def group_rank(self, world_rank: int) -> Optional[int]:
        """World rank -> communicator-local rank (identity for the world)."""
        return world_rank if 0 <= world_rank < self.size else None

    # -- sub-communicators (MPI_Comm_split / MPI_Comm_group / Cart) ---------
    def group(self, ranks: Sequence[int]) -> "CommGroup":
        """A sub-communicator over ``ranks`` (group-local order as given).

        Central construction: call once, share the returned object among
        the member ranks.  Every call mints a fresh context id, so two
        groups over the same ranks still have disjoint tag spaces (as two
        ``MPI_Comm_dup``-ed communicators would).
        """
        return CommGroup(self, ranks, ("g", next(self._group_seq)))

    def split(self, color: Any, key: int = 0, *, rank: int) -> "GroupHandle":
        """MPI_Comm_split: a collective group construction.

        Every world rank calls once per split *generation* (its n-th call
        joins the n-th split, matching MPI's same-order rule).  Returns a
        handle that completes when the last rank has called; ``result`` is
        this rank's :class:`CommGroup` — the ranks that passed an equal
        ``color``, ordered by ``(key, world rank)`` — or ``None`` when
        ``color`` is ``None`` (MPI_UNDEFINED).  The handle is task-aware:
        ``tac.wait(handle)`` inside a task pauses instead of spinning.
        """
        if not 0 <= rank < self.size:
            raise ValueError(f"rank {rank} out of range for size {self.size}")
        handle = GroupHandle()
        if self._revoked or self._failed:
            # A split needs every world rank; with a dead member it can
            # never complete — fail fast (survivors shrink() instead).
            return self._failed_op(handle, rank, rank)
        ready = None
        with self._lock:
            gen = self._split_calls[rank]
            self._split_calls[rank] += 1
            entry = self._splits.setdefault(gen, {})
            entry[rank] = (color, key, handle)
            if len(entry) == self.size:
                ready = self._splits.pop(gen)
        if ready is not None:
            # Build the groups and complete the handles OUTSIDE the world
            # lock: a completing handle may wake a waiter that immediately
            # posts messages (which need the lock).
            by_color: Dict[Any, List[Tuple[int, int]]] = {}
            for r, (c, k, _) in ready.items():
                if c is not None:
                    by_color.setdefault(c, []).append((k, r))
            groups = {
                c: CommGroup(self, [r for _, r in sorted(members)],
                             ("split", gen, c))
                for c, members in by_color.items()}
            for r, (c, _, h) in ready.items():
                h.complete(None if c is None else groups[c])
        return handle

    def cart_create(self, dims: Sequence[int],
                    periodic: Any = False) -> "CartGroup":
        """Cartesian sub-communicator over the first ``prod(dims)`` ranks
        (MPI_Cart_create, row-major rank order).  ``periodic`` is a bool or
        a per-dimension sequence."""
        n = math.prod(int(d) for d in dims)
        if n > self.size:
            raise ValueError(f"cartesian grid {tuple(dims)} needs {n} ranks,"
                             f" world has {self.size}")
        return CartGroup(self, range(n), ("cart", next(self._group_seq)),
                         dims, periodic)

    def dist_graph_create(
            self, adjacency: Sequence[Sequence[int]],
            directed: bool = False) -> "DistGraphGroup":
        """Distributed-graph sub-communicator over the first
        ``len(adjacency)`` ranks (the ``MPI_Dist_graph_create_adjacent``
        analogue for unstructured meshes).

        ``adjacency[r]`` lists rank ``r``'s neighbours (group-local
        numbering).  Like :meth:`cart_create` the construction is
        central: build once, share the group.  By default the adjacency
        must be symmetric (every edge declared by both endpoints — the
        ``sources == destinations`` case of the MPI call, which is what
        an unstructured-mesh halo exchange needs) and self-loop-free.
        With ``directed=True``, ``adjacency[r]`` lists rank ``r``'s
        *out*-neighbours (its destinations) and edges may be one-way —
        the general ``MPI_Dist_graph_create_adjacent`` case; in-neighbour
        lists are derived (:meth:`DistGraphGroup.in_neighbor_dirs`).  The
        group's :meth:`DistGraphGroup.topology` feeds
        :func:`repro.core.schedule.build_neighbor` exactly like a
        Cartesian grid's, so :class:`~repro.core.collectives.HaloExchange`
        and ``Collectives.neighbor_alltoall`` work unchanged over it.
        """
        n = len(adjacency)
        if n > self.size:
            raise ValueError(f"graph with {n} ranks exceeds world size "
                             f"{self.size}")
        return DistGraphGroup(self, range(n),
                              ("graph", next(self._group_seq)), adjacency,
                              directed=directed)

    # -- ULFM failure detection, revoke, and shrink -------------------------
    @property
    def failed(self) -> frozenset:
        """The dead world ranks (MPI_Comm_failure_ack / get_failed)."""
        with self._lock:
            return frozenset(self._failed)

    @property
    def alive(self) -> Tuple[int, ...]:
        """The surviving world ranks, ascending."""
        with self._lock:
            return tuple(r for r in range(self.size)
                         if r not in self._failed)

    @property
    def revoked(self) -> bool:
        return self._revoked

    def fail_rank(self, rank: int) -> None:
        """Kill ``rank``: the failure-detection entry point.

        Every *pending* send/recv naming the dead rank completes
        erroneously with :class:`RankFailedError` — pushed through the
        handles' completion callbacks, so both notification backends
        observe the failure with zero new polling.  Every *future* op
        naming it fails at post time.  Pending ``split`` generations can
        never complete (they need all ranks) and are failed too.  The
        communicator epoch is bumped, invalidating epoch-keyed compiled
        plans (:func:`repro.core.program.epoch_of`).  Idempotent.
        """
        if not 0 <= rank < self.size:
            raise ValueError(f"rank {rank} out of range for size {self.size}")
        to_fail: List[EventHandle] = []
        with self._lock:
            if rank in self._failed:
                return
            self._failed.add(rank)
            self.epoch += 1
            for store in (self._msgs, self._recvs):
                for key in [k for k in store if rank in k[:2]]:
                    to_fail.extend(store.pop(key))
            split_handles = [h for entry in self._splits.values()
                             for (_c, _k, h) in entry.values()]
            self._splits.clear()
            ready = self._shrink_ready_locked()
        # Fail OUTSIDE the lock: failing completes the handles, and a
        # completion callback may post messages (which need the lock).
        exc = RankFailedError(rank)
        for h in to_fail:
            h.fail(exc)
        for h in split_handles:
            h.fail(exc)
        # A shrink agreement pending on only this rank's vote is now
        # decided: the dead rank no longer gets a say.
        self._complete_shrinks(ready)

    def revoke(self) -> None:
        """Revoke the communicator (MPI_Comm_revoke).

        Any survivor that observes a :class:`RankFailedError` calls this
        to propagate the failure: every pending operation — whoever it
        names — completes erroneously with :class:`CommRevokedError`, and
        new operations fail at post time, so no peer can stay parked on a
        handle whose partner aborted.  The revocation stays in effect
        until a :meth:`shrink` agreement completes.  Idempotent per
        revocation window.
        """
        with self._lock:
            if self._revoked:
                return
            self._revoked = True
            self.epoch += 1
            to_fail = [h for hs in self._msgs.values() for h in hs]
            to_fail += [h for hs in self._recvs.values() for h in hs]
            self._msgs.clear()
            self._recvs.clear()
            split_handles = [h for entry in self._splits.values()
                             for (_c, _k, h) in entry.values()]
            self._splits.clear()
        exc = CommRevokedError()
        for h in to_fail:
            h.fail(exc)
        for h in split_handles:
            h.fail(exc)

    def revoke_group(self, gid: Any) -> None:
        """Revoke one sub-communicator's traffic only (its tag space)."""
        def is_group_tag(tag: Any) -> bool:
            return (isinstance(tag, tuple) and len(tag) == 3
                    and tag[0] == "grp" and tag[1] == gid)
        with self._lock:
            self.epoch += 1
            to_fail = []
            for store in (self._msgs, self._recvs):
                for key in [k for k in store if is_group_tag(k[2])]:
                    to_fail.extend(store.pop(key))
        exc = CommRevokedError(message=f"communicator {gid!r} revoked")
        for h in to_fail:
            h.fail(exc)

    def shrink(self, *, rank: int) -> GroupHandle:
        """ULFM MPI_Comm_shrink: survivors agree on a shrunken communicator.

        A collective agreement among the *live* ranks (same generation
        counting as :meth:`split`): the returned handle completes once
        every survivor of this generation has called, with a
        :class:`CommGroup` over the survivors (ascending world-rank
        order, dense group-local numbering) as its result — all callers
        of one generation share the same group object, so compiled-plan
        caches are shared too.  Completing the agreement ends any active
        :meth:`revoke` window.  A caller that is itself dead — or dies
        mid-agreement — gets its handle failed instead; the agreement
        then completes without its vote (``fail_rank`` re-checks pending
        generations).  The handle is task-aware like ``split``'s.
        """
        if not 0 <= rank < self.size:
            raise ValueError(f"rank {rank} out of range for size {self.size}")
        handle = GroupHandle()
        with self._lock:
            if rank in self._failed:
                dead_caller = True
                ready: List[tuple] = []
            else:
                dead_caller = False
                gen = self._shrink_calls[rank]
                self._shrink_calls[rank] += 1
                self._shrinks.setdefault(gen, {})[rank] = handle
                ready = self._shrink_ready_locked()
        if dead_caller:
            handle.fail(RankFailedError(rank))
            return handle
        self._complete_shrinks(ready)
        return handle

    def _shrink_ready_locked(self) -> List[tuple]:
        """Pop the shrink generations whose surviving voters all arrived.

        Caller holds ``_lock``.  Returns ``(gen, votes, survivors,
        epoch)`` records for :meth:`_complete_shrinks` to finish outside
        the lock.
        """
        survivors = tuple(r for r in range(self.size)
                          if r not in self._failed)
        ready = []
        for gen in sorted(self._shrinks):
            entry = self._shrinks[gen]
            if all(r in entry for r in survivors):
                ready.append((gen, self._shrinks.pop(gen), survivors,
                              self.epoch))
        return ready

    def _complete_shrinks(self, ready: List[tuple]) -> None:
        for gen, entry, survivors, epoch in ready:
            group = CommGroup(self, survivors, ("shrink", epoch, gen))
            with self._lock:
                # The agreement is the recovery point: survivors hold a
                # working communicator again, so the revocation window
                # closes before any completion callback can observe it.
                self._revoked = False
            for r, h in entry.items():
                if r in self._failed:
                    h.fail(RankFailedError(r))
                else:
                    h.complete(group)


class GroupHandle(EventHandle):
    """Completion of a collective group construction (``CommWorld.split``)."""


class CommGroup:
    """An ordered subset of a CommWorld's ranks — the MPI sub-communicator.

    Group-local ranks ``0..size-1`` map onto the parent world's ranks in
    ``ranks`` order.  All traffic flows through the parent world, but every
    tag is namespaced by the group's context id, so a group's channels can
    never match the world's (or another group's) — the isolated context of
    an MPI communicator.  Non-overtaking order per ``(src, dst, tag)`` is
    inherited from the world.  A :class:`~repro.core.collectives.Collectives`
    instance accepts a group anywhere it accepts a world.
    """

    def __init__(self, world: CommWorld, ranks: Sequence[int],
                 gid: Any) -> None:
        ranks = tuple(int(r) for r in ranks)
        if not ranks:
            raise ValueError("a group needs at least one rank")
        if len(set(ranks)) != len(ranks):
            raise ValueError(f"duplicate ranks in group: {ranks}")
        for r in ranks:
            if not 0 <= r < world.size:
                raise ValueError(f"world rank {r} out of range "
                                 f"(world size {world.size})")
        self.world = world
        self.ranks = ranks
        self.gid = gid
        self._to_group = {wr: gr for gr, wr in enumerate(ranks)}

    @property
    def size(self) -> int:
        return len(self.ranks)

    @property
    def stats(self) -> dict:
        return self.world.stats

    def __repr__(self) -> str:
        return (f"{type(self).__name__}(gid={self.gid!r}, "
                f"ranks={self.ranks})")

    # -- rank translation (MPI_Group_translate_ranks) -----------------------
    def world_rank(self, rank: int) -> int:
        """Group-local rank -> parent world rank."""
        self._check(rank)
        return self.ranks[rank]

    def group_rank(self, world_rank: int) -> Optional[int]:
        """Parent world rank -> group-local rank (None if not a member)."""
        return self._to_group.get(world_rank)

    def translate(self, rank: int, other: "CommGroup") -> Optional[int]:
        """This group's ``rank`` in ``other``'s numbering (None if absent)."""
        return other.group_rank(self.world_rank(rank))

    def translate_many(self, ranks: Sequence[int],
                       other: "CommGroup") -> List[Optional[int]]:
        """MPI_Group_translate_ranks: batch :meth:`translate`."""
        return [self.translate(r, other) for r in ranks]

    # -- failure model (delegated to the parent world) ----------------------
    @property
    def epoch(self) -> int:
        """The parent world's communicator epoch (conservative: any
        failure/revoke anywhere invalidates this group's compiled plans
        too — see :func:`repro.core.program.epoch_of`)."""
        return self.world.epoch

    @property
    def failed(self) -> frozenset:
        """The dead *group-local* ranks of this group."""
        return frozenset(gr for gr, wr in enumerate(self.ranks)
                         if wr in self.world.failed)

    def revoke(self) -> None:
        """Revoke this sub-communicator only (its tag space): pending
        group traffic fails with :class:`CommRevokedError`; the world and
        sibling groups are untouched."""
        self.world.revoke_group(self.gid)

    # -- rebuild helpers (the recovery path) --------------------------------
    def cart(self, dims: Sequence[int], periodic: Any = False) -> "CartGroup":
        """Re-shape this group's members as a Cartesian topology.

        The recovery step after :meth:`CommWorld.shrink`: the shrunken
        group's dense ranks get grid coordinates again so persistent
        neighbourhood schedules can be rebuilt.  A fresh context id is
        minted — old in-flight tags can never match the new topology.
        """
        n = math.prod(int(d) for d in dims)
        if n != self.size:
            raise ValueError(f"cartesian grid {tuple(dims)} needs {n} "
                             f"ranks, group has {self.size}")
        return CartGroup(self.world, self.ranks,
                         ("cart", next(self.world._group_seq)),
                         dims, periodic)

    def graph(self, adjacency: Sequence[Sequence[int]],
              directed: bool = False) -> "DistGraphGroup":
        """Re-shape this group's members as a distributed graph (the
        unstructured-mesh sibling of :meth:`cart`)."""
        if len(adjacency) != self.size:
            raise ValueError(f"graph with {len(adjacency)} ranks does not "
                             f"cover group size {self.size}")
        return DistGraphGroup(self.world, self.ranks,
                              ("graph", next(self.world._group_seq)),
                              adjacency, directed=directed)

    # -- point-to-point (group-local ranks, namespaced tags) ----------------
    def _check(self, rank: int) -> None:
        if not 0 <= rank < len(self.ranks):
            raise ValueError(f"group rank {rank} out of range "
                             f"(group size {len(self.ranks)})")

    def _tag(self, tag: Any) -> Any:
        return ("grp", self.gid, tag)

    def isend(self, payload: Any, *, src: int, dst: int, tag: Any = 0,
              synchronous: bool = False) -> _SendHandle:
        self._check(src)
        self._check(dst)
        return self.world.isend(payload, src=self.ranks[src],
                                dst=self.ranks[dst], tag=self._tag(tag),
                                synchronous=synchronous)

    def irecv(self, *, src: int, dst: int, tag: Any = 0) -> _RecvHandle:
        self._check(src)
        self._check(dst)
        return self.world.irecv(src=self.ranks[src], dst=self.ranks[dst],
                                tag=self._tag(tag))

    def recv(self, *, src: int, dst: int, tag: Any = 0) -> Any:
        return wait(self.irecv(src=src, dst=dst, tag=tag))

    def send(self, payload: Any, *, src: int, dst: int, tag: Any = 0) -> None:
        wait(self.isend(payload, src=src, dst=dst, tag=tag))

    def ssend(self, payload: Any, *, src: int, dst: int, tag: Any = 0) -> None:
        wait(self.isend(payload, src=src, dst=dst, tag=tag, synchronous=True))


class _NeighborTopology:
    """Shared ``topology()`` for groups with persistent neighbour lists
    (:class:`CartGroup`, :class:`DistGraphGroup`)."""

    def topology(self):
        """All ranks' neighbour lists as one hashable tuple.

        ``topology()[r] == tuple(neighbor_dirs(r))`` — the value that
        keys the cached neighbourhood schedule
        (:func:`repro.core.schedule.build_neighbor`): two topologies of
        the same shape share one schedule object.
        """
        return tuple(tuple(self.neighbor_dirs(r))
                     for r in range(self.size))


class CartGroup(_NeighborTopology, CommGroup):
    """Cartesian process topology over a sub-communicator (MPI_Cart_create).

    Group-local ranks are laid out row-major over ``dims``; ``periodic``
    marks wrap-around dimensions.  The neighbourhood collectives
    (:class:`~repro.core.collectives.HaloExchange`,
    ``Collectives.neighbor_alltoall``) take their persistent neighbour
    lists from this topology.
    """

    def __init__(self, world: CommWorld, ranks: Sequence[int], gid: Any,
                 dims: Sequence[int], periodic: Any = False) -> None:
        dims = tuple(int(d) for d in dims)
        if not dims or any(d <= 0 for d in dims):
            raise ValueError(f"invalid cartesian dims {dims}")
        if isinstance(periodic, (bool, int)):
            periodic = (bool(periodic),) * len(dims)
        else:
            periodic = tuple(bool(p) for p in periodic)
            if len(periodic) != len(dims):
                raise ValueError("periodic must match dims "
                                 f"({len(periodic)} != {len(dims)})")
        super().__init__(world, ranks, gid)
        if math.prod(dims) != self.size:
            raise ValueError(f"dims {dims} do not cover {self.size} ranks")
        self.dims = dims
        self.periodic = periodic

    @property
    def ndim(self) -> int:
        return len(self.dims)

    def coords(self, rank: int) -> Tuple[int, ...]:
        """Group rank -> cartesian coordinates (row-major)."""
        self._check(rank)
        out = []
        for d in reversed(self.dims):
            rank, c = divmod(rank, d)
            out.append(c)
        return tuple(reversed(out))

    def rank_at(self, coords: Sequence[int]) -> Optional[int]:
        """Coordinates -> group rank; periodic dims wrap, out-of-range
        coordinates in non-periodic dims give ``None`` (off the grid)."""
        if len(coords) != self.ndim:
            raise ValueError(f"expected {self.ndim} coordinates, "
                             f"got {len(coords)}")
        rank = 0
        for c, d, p in zip(coords, self.dims, self.periodic):
            if p:
                c %= d
            elif not 0 <= c < d:
                return None
            rank = rank * d + c
        return rank

    def shift(self, rank: int, dim: int,
              disp: int = 1) -> Tuple[Optional[int], Optional[int]]:
        """MPI_Cart_shift: ``(source, destination)`` for a shift of
        ``disp`` along ``dim`` — either end is ``None`` off a
        non-periodic edge."""
        if not 0 <= dim < self.ndim:
            raise ValueError(f"dim {dim} out of range for {self.dims}")
        c = list(self.coords(rank))
        dst = list(c)
        dst[dim] += disp
        src = list(c)
        src[dim] -= disp
        return self.rank_at(src), self.rank_at(dst)

    def neighbor_dirs(
            self, rank: int) -> List[Tuple[Tuple[int, int], int]]:
        """Persistent neighbour list: ``[((dim, ±1), neighbour rank)]`` in
        deterministic (dim, -1 then +1) order, off-grid directions
        omitted.  A direction is *from this rank's perspective*: ``(0, -1)``
        is the neighbour one step down in dimension 0."""
        dirs = []
        for dim in range(self.ndim):
            for disp in (-1, 1):
                _, dst = self.shift(rank, dim, disp)
                if dst is not None and dst != rank:
                    dirs.append(((dim, disp), dst))
        return dirs

    def neighbors(self, rank: int) -> List[int]:
        """Neighbour group ranks in ``neighbor_dirs`` order."""
        return [nbr for _, nbr in self.neighbor_dirs(rank)]


class DistGraphGroup(_NeighborTopology, CommGroup):
    """Unstructured-graph process topology (MPI_Dist_graph_create_adjacent).

    The non-Cartesian sibling of :class:`CartGroup`: neighbour lists come
    from an explicit adjacency instead of grid coordinates.  In the
    symmetric (default) case a neighbour *direction* is ``((lo, hi), ±1)``
    — the undirected edge's endpoint pair plus which way along it this
    rank sends (``+1`` from the lower-ranked endpoint) — so reciprocity
    holds exactly as on a grid: rank ``r``'s direction ``d`` toward ``q``
    is matched by ``q``'s direction ``(d[0], -d[1])`` toward ``r``, which
    is what :func:`repro.core.schedule.build_neighbor` requires of a
    topology.

    With ``directed=True`` the adjacency lists *out*-neighbours and edges
    may be one-way: the edge ``u → v`` is the send direction
    ``((u, v), +1)`` at ``u`` and the receive direction ``((u, v), -1)``
    at ``v`` (:meth:`in_neighbor_dirs`).  A graph declaring both
    ``u → v`` and ``v → u`` therefore carries two independent one-way
    edges with distinct direction labels.  :meth:`in_topology` hands the
    per-rank receive-direction lists to ``build_neighbor`` so asymmetric
    exchanges validate and schedule correctly.
    """

    def __init__(self, world: CommWorld, ranks: Sequence[int], gid: Any,
                 adjacency: Sequence[Sequence[int]],
                 directed: bool = False) -> None:
        super().__init__(world, ranks, gid)
        self.directed = bool(directed)
        adj = []
        for r, nbrs in enumerate(adjacency):
            nbrs = sorted({int(q) for q in nbrs})
            for q in nbrs:
                if not 0 <= q < self.size:
                    raise ValueError(f"rank {r}: neighbour {q} out of "
                                     f"range for graph size {self.size}")
                if q == r:
                    raise ValueError(f"rank {r}: self-loop in adjacency")
            adj.append(tuple(nbrs))
        self.adjacency = tuple(adj)
        if self.directed:
            in_adj: List[List[int]] = [[] for _ in range(self.size)]
            for r, nbrs in enumerate(self.adjacency):
                for q in nbrs:
                    in_adj[q].append(r)
            self.in_adjacency = tuple(tuple(sorted(s)) for s in in_adj)
        else:
            for r, nbrs in enumerate(self.adjacency):
                for q in nbrs:
                    if r not in self.adjacency[q]:
                        raise ValueError(
                            f"asymmetric adjacency: {r} lists {q} but {q} "
                            f"does not list {r} (every edge must be "
                            f"declared by both endpoints; pass "
                            f"directed=True for one-way edges)")
            self.in_adjacency = self.adjacency

    def neighbor_dirs(self, rank: int) -> List[Tuple[Tuple[Any, int], int]]:
        """Persistent *send* neighbour list in ascending-neighbour order
        (deterministic, like the grid's): ``[(((lo, hi), ±1), neighbour)]``
        for a symmetric graph, ``[(((rank, q), +1), q)]`` for a directed
        one."""
        self._check(rank)
        if self.directed:
            return [(((rank, q), 1), q) for q in self.adjacency[rank]]
        return [(((min(rank, q), max(rank, q)), 1 if rank < q else -1), q)
                for q in self.adjacency[rank]]

    def in_neighbor_dirs(
            self, rank: int) -> List[Tuple[Tuple[Any, int], int]]:
        """Persistent *receive* neighbour list ``[(direction, source)]``.

        For a symmetric graph this equals :meth:`neighbor_dirs` (every
        receive direction is also a send direction); for a directed graph
        it lists the in-edges ``(((q, rank), -1), q)``.
        """
        self._check(rank)
        if not self.directed:
            return self.neighbor_dirs(rank)
        return [(((q, rank), -1), q) for q in self.in_adjacency[rank]]

    def in_topology(self):
        """Per-rank receive-direction lists for
        :func:`repro.core.schedule.build_neighbor`'s ``in_topology``
        argument — ``None`` for a symmetric graph (receives mirror
        sends), a hashable tuple-of-tuples of direction labels for a
        directed one."""
        if not self.directed:
            return None
        return tuple(tuple(d for d, _ in self.in_neighbor_dirs(r))
                     for r in range(self.size))

    def neighbors(self, rank: int) -> List[int]:
        """Out-neighbour group ranks in ``neighbor_dirs`` order."""
        return [nbr for _, nbr in self.neighbor_dirs(rank)]

    def in_neighbors(self, rank: int) -> List[int]:
        """In-neighbour group ranks in ``in_neighbor_dirs`` order."""
        return [nbr for _, nbr in self.in_neighbor_dirs(rank)]


# ---------------------------------------------------------------------------
# The AsyncHandle protocol coercion — ONE async-wait surface
# ---------------------------------------------------------------------------
def as_handle(obj: Any) -> AsyncHandle:
    """Coerce ``obj`` to the :class:`AsyncHandle` protocol.

    The single normalisation point of the public async surface: whatever
    :func:`wait`/:func:`iwait`/:func:`iwaitall`,
    :meth:`repro.core.executor.TaskRuntime.taskwait` and
    :meth:`repro.core.continuations.ContinuationEngine.attach` accept
    goes through here.  Accepted inputs:

    * anything already satisfying the protocol (``test()``/``wait()``/
      ``result`` — every :class:`AsyncHandle` subclass,
      :class:`~repro.core.collectives.CollectiveHandle`, and
      :class:`~repro.core.continuations.Continuation`), returned as-is;
    * a ``concurrent.futures.Future`` (wrapped in :class:`FutureHandle`);
    * a pytree of JAX arrays (wrapped in :class:`ArrayHandle` — XLA's
      async dispatch is the in-flight operation);
    * a list/tuple of any of the above (wrapped in
      :class:`CompositeHandle`).
    """
    if isinstance(obj, AsyncHandle):
        return obj
    if callable(getattr(obj, "test", None)) and \
            callable(getattr(obj, "wait", None)):
        return obj          # duck-typed protocol (e.g. Continuation)
    if isinstance(obj, concurrent.futures.Future):
        return FutureHandle(obj)
    if isinstance(obj, (list, tuple)):
        return CompositeHandle([as_handle(h) for h in obj])
    return ArrayHandle(obj)


# ---------------------------------------------------------------------------
# Deprecated ticket-pool shims (pre-fold entry points)
# ---------------------------------------------------------------------------
def _ticket_pool_deprecated(name: str) -> None:
    warnings.warn(
        f"tac.{name} is deprecated: the TAC ticket pool was folded into "
        f"the runtime's ContinuationEngine (runtime.continuations), the "
        f"single completion dispatcher for both notify backends; attach "
        f"callbacks there instead",
        DeprecationWarning, stacklevel=3)


class _Ticket:
    """Deprecated record of the retired ticket pool (shim)."""

    __slots__ = ("handle", "waiter", "counter", "n_events")

    def __init__(self, handle: AsyncHandle,
                 waiter: Optional[BlockingContext] = None,
                 counter: Optional[EventCounter] = None,
                 n_events: int = 1) -> None:
        _ticket_pool_deprecated("_Ticket")
        self.handle = handle
        self.waiter = waiter      # blocking mode: context to unblock
        self.counter = counter    # non-blocking mode: counter to decrease
        self.n_events = n_events


class _TicketPool:
    """Deprecated facade over the runtime's :class:`ContinuationEngine`.

    The ticket pool is no longer an independent completion path: ``add``
    attaches the ticket's unblock/decrease action to the continuation
    engine (which re-tests push-less handles from its fallback poll list
    — the old pool's discipline), and ``pending`` reads the engine's
    fallback-list length.  No polling service of its own is registered.
    """

    def __init__(self, runtime: TaskRuntime) -> None:
        _ticket_pool_deprecated("_TicketPool")
        self._runtime = runtime

    def add(self, ticket: _Ticket) -> None:
        eng = self._runtime.continuations
        if ticket.waiter is not None:
            waiter = ticket.waiter
            eng.attach(ticket.handle, lambda: unblock_task(waiter))
        if ticket.counter is not None:
            counter, n = ticket.counter, ticket.n_events
            eng.attach(ticket.handle,
                       lambda: decrease_task_event_counter(counter, n))

    @property
    def pending(self) -> int:
        return self._runtime.continuations.polled


def _pool(runtime: TaskRuntime) -> _TicketPool:
    _ticket_pool_deprecated("_pool")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return _TicketPool(runtime)


def _use_continuations(runtime: TaskRuntime) -> bool:
    """Deprecated: the continuation engine is the only completion
    dispatcher now; ``notify="polling"`` is a compatibility mode of the
    SAME engine (``push=False``), so there is no branch left to take."""
    _ticket_pool_deprecated("_use_continuations")
    return True


# ---------------------------------------------------------------------------
# The two interoperability modes
# ---------------------------------------------------------------------------
def wait(handle: Any) -> Any:
    """Task-aware blocking wait (§6.1, Fig. 3).

    Accepts anything :func:`as_handle` accepts.  Inside a task with
    TASK_MULTIPLE enabled: test; if incomplete, attach a resume callback
    to the runtime's continuation engine and *pause the task* — the
    worker runs other ready tasks until the completion dispatch unblocks
    us (pushed at match time under ``notify="continuation"``; re-tested
    from the engine's poll list under the ``notify="polling"``
    compatibility mode).  Otherwise: plain blocking wait (the PMPI path).
    """
    handle = as_handle(handle)
    task = current_task()
    if is_enabled() and task is not None:
        if handle.test():
            return handle.result
        ctx = get_current_blocking_context()
        task._runtime.continuations.attach(
            handle, lambda: unblock_task(ctx))
        block_current_task(ctx)
        return handle.result
    handle.wait()
    return handle.result


def waitall(handles: Sequence[Any]) -> List[Any]:
    """Blocking wait on several handles with a single pause/resume cycle."""
    coerced = [as_handle(h) for h in handles]
    wait(CompositeHandle(coerced))
    return [h.result for h in coerced]


def iwait(handle: Any) -> None:
    """TAMPI_Iwait (§6.2, Fig. 4): bind ``handle`` to the task's events.

    Accepts anything :func:`as_handle` accepts and returns immediately.
    The calling task's dependencies are released only once the task
    finishes *and* the handle completes.  The buffers produced by the
    operation must not be consumed inside this task after the call —
    consumers declare dependencies instead (Fig. 5).
    """
    handle = as_handle(handle)
    task = current_task()
    if is_enabled() and task is not None:
        if handle.test():
            return
        cnt = get_current_event_counter()
        increase_current_task_event_counter(cnt, 1)
        if _tr.TRACING:
            _tr.TRACER.instant("handle", "bind", rank=task.rank,
                               task=task.name, n_events=1)

            def _decrease(cnt=cnt, task=task) -> None:
                decrease_task_event_counter(cnt, 1)
                # §4.3 made visible: the dependency release deferred to
                # completion time, firing from the dispatch thread.
                _tr.TRACER.instant("handle", "dep-release", rank=task.rank,
                                   task=task.name, n_events=1)

            task._runtime.continuations.attach(handle, _decrease)
            return
        task._runtime.continuations.attach(
            handle, lambda: decrease_task_event_counter(cnt, 1))
        return
    handle.wait()


def iwaitall(handles: Sequence[Any]) -> None:
    """TAMPI_Iwaitall (§6.2): bind several handles to the task's events."""
    task = current_task()
    if is_enabled() and task is not None:
        pending = [h for h in map(as_handle, handles) if not h.test()]
        if not pending:
            return
        cnt = get_current_event_counter()
        increase_current_task_event_counter(cnt, len(pending))
        n = len(pending)
        if _tr.TRACING:
            _tr.TRACER.instant("handle", "bind", rank=task.rank,
                               task=task.name, n_events=n)

            def _decrease(cnt=cnt, n=n, task=task) -> None:
                decrease_task_event_counter(cnt, n)
                _tr.TRACER.instant("handle", "dep-release", rank=task.rank,
                                   task=task.name, n_events=n)

            task._runtime.continuations.attach(pending, _decrease)
            return
        task._runtime.continuations.attach(
            pending, lambda: decrease_task_event_counter(cnt, n))
        return
    for h in map(as_handle, handles):
        h.wait()
