"""TAC — Task-Aware Collectives: the TAMPI analogue for JAX (paper §6).

TAMPI intercepts MPI calls and re-expresses them against the pause/resume and
external-events APIs.  In JAX the "MPI layer" is the asynchronous dispatch
machinery: every ``jax.Array`` is a future (``.is_ready()`` is the
non-blocking completion test, ``jax.block_until_ready`` the blocking wait),
``jax.device_put`` is an asynchronous transfer, and host-side channels give
point-to-point semantics between logical ranks.  TAC wraps those operations
in the two modes the paper defines:

* **Blocking mode** (§6.1, Fig. 3): ``tac.wait(handle)`` from inside a task
  converts a blocking wait into *test → register ticket → pause task*; a
  polling service tests the pending tickets and unblocks tasks on
  completion.  The hardware thread never blocks inside the "MPI library".

* **Non-blocking mode** (§6.2, Fig. 4): ``tac.iwait(handle)`` /
  ``tac.iwaitall(handles)`` bind the handles to the calling task's event
  counter and return immediately.  The task may finish; its dependencies are
  released only when the bound operations complete.  No context switch, no
  live stack, no extra scheduler round trips.

Both modes are enabled by initialising TAC with the ``TASK_MULTIPLE``
threading level (§6.3).  Without it, the wrappers fall back to the plain
blocking wait — the "PMPI" path of Fig. 3/4 — and programs must serialise
communication tasks themselves (the *sentinel* pattern, §7.1).
"""

from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax

from .events import (current_task, get_current_blocking_context,
                     get_current_event_counter,
                     increase_current_task_event_counter,
                     decrease_task_event_counter, block_current_task,
                     unblock_task, BlockingContext, EventCounter)
from .executor import TaskRuntime

# -- threading levels (§6.3) -------------------------------------------------
THREAD_SINGLE = 0
THREAD_FUNNELED = 1
THREAD_SERIALIZED = 2
THREAD_MULTIPLE = 3
TASK_MULTIPLE = 4  # monotonically greater than THREAD_MULTIPLE (§6.3)

_provided_level = THREAD_MULTIPLE
_level_lock = threading.Lock()


def init(required: int = TASK_MULTIPLE) -> int:
    """Initialise TAC, requesting a threading level (cf. MPI_Init_thread).

    Returns the *provided* level.  ``TASK_MULTIPLE`` is always available in
    this runtime; programs may still request less to emulate legacy MPI
    libraries (the benchmarks use this to build the Sentinel versions).
    """
    global _provided_level
    with _level_lock:
        _provided_level = min(required, TASK_MULTIPLE)
        return _provided_level


def query_thread() -> int:
    return _provided_level


def is_enabled() -> bool:
    """True when the TASK_MULTIPLE interoperability mechanism is active."""
    return _provided_level >= TASK_MULTIPLE


# ---------------------------------------------------------------------------
# Asynchronous handles ("MPI_Request" analogues)
# ---------------------------------------------------------------------------
class AsyncHandle:
    """A testable/waitable in-flight operation."""

    def test(self) -> bool:
        raise NotImplementedError

    def wait(self) -> Any:
        """OS-level blocking wait (the 'PMPI' path). Returns the result."""
        raise NotImplementedError

    @property
    def result(self) -> Any:
        return getattr(self, "_result", None)


class ArrayHandle(AsyncHandle):
    """Completion of asynchronously dispatched JAX arrays.

    ``jax.Array.is_ready()`` is the non-blocking completion test — the exact
    analogue of ``MPI_Test`` for XLA's async dispatch.
    """

    def __init__(self, value: Any) -> None:
        self._result = value
        self._leaves = [x for x in jax.tree_util.tree_leaves(value)
                        if hasattr(x, "is_ready")]

    def test(self) -> bool:
        return all(x.is_ready() for x in self._leaves)

    def wait(self) -> Any:
        jax.block_until_ready(self._result)
        return self._result


class EventHandle(AsyncHandle):
    """A manually completed handle (asynchronous host work, I/O, ...)."""

    def __init__(self) -> None:
        self._event = threading.Event()
        self._result: Any = None

    def complete(self, result: Any = None) -> None:
        self._result = result
        self._event.set()

    def test(self) -> bool:
        return self._event.is_set()

    def wait(self) -> Any:
        self._event.wait()
        return self._result


class FutureHandle(AsyncHandle):
    """Adapter for ``concurrent.futures.Future``."""

    def __init__(self, future: Any) -> None:
        self._future = future

    def test(self) -> bool:
        return self._future.done()

    def wait(self) -> Any:
        return self._future.result()

    @property
    def result(self) -> Any:
        return self._future.result() if self._future.done() else None


class CompositeHandle(AsyncHandle):
    def __init__(self, handles: Sequence[AsyncHandle]) -> None:
        self._handles = list(handles)

    def test(self) -> bool:
        return all(h.test() for h in self._handles)

    def wait(self) -> Any:
        return [h.wait() for h in self._handles]

    @property
    def result(self) -> Any:
        return [h.result for h in self._handles]


def run_async(fn: Callable[..., Any], *args: Any, **kwargs: Any) -> ArrayHandle:
    """Dispatch a (jitted) computation and return its completion handle.

    JAX dispatch is asynchronous, so this returns as soon as the work is
    enqueued on the device — the handle completes when the result arrays are
    materialised.
    """
    return ArrayHandle(fn(*args, **kwargs))


def transfer(value: Any, target: Any) -> ArrayHandle:
    """Asynchronous device transfer (the point-to-point data motion)."""
    return ArrayHandle(jax.device_put(value, target))


# ---------------------------------------------------------------------------
# CommWorld: logical ranks with MPI point-to-point semantics
# ---------------------------------------------------------------------------
class _SendHandle(EventHandle):
    def __init__(self, payload: Any, synchronous: bool) -> None:
        super().__init__()
        self.payload = payload
        if not synchronous:
            # Buffered send: locally complete immediately (MPI_Isend on a
            # small message); synchronous send completes on match (MPI_Issend).
            self.complete(payload)


class _RecvHandle(EventHandle):
    pass


class CommWorld:
    """``size`` logical ranks with ordered, tagged point-to-point messaging.

    Matching follows MPI semantics: messages between the same (src, dst, tag)
    triple are non-overtaking; matching is eager (performed at post time
    under the world lock).  Payloads are passed by reference — callers
    sharing device arrays get zero-copy semantics on a single host, which is
    the honest analogue of intra-node MPI.
    """

    def __init__(self, size: int) -> None:
        self.size = size
        self._lock = threading.Lock()
        self._msgs: dict = {}   # (src, dst, tag) -> list[_SendHandle]
        self._recvs: dict = {}  # (src, dst, tag) -> list[_RecvHandle]
        self.stats = {"messages": 0, "bytes": 0}

    def _key(self, src: int, dst: int, tag: Any) -> Tuple[int, int, Any]:
        return (src, dst, tag)

    def isend(self, payload: Any, *, src: int, dst: int, tag: Any = 0,
              synchronous: bool = False) -> _SendHandle:
        if not (0 <= src < self.size and 0 <= dst < self.size):
            raise ValueError(f"rank out of range: {src}->{dst}")
        h = _SendHandle(payload, synchronous)
        key = self._key(src, dst, tag)
        with self._lock:
            self.stats["messages"] += 1
            recvs = self._recvs.get(key)
            if recvs:
                r = recvs.pop(0)
                r.complete(payload)
                h.complete(payload)
            else:
                self._msgs.setdefault(key, []).append(h)
        return h

    def irecv(self, *, src: int, dst: int, tag: Any = 0) -> _RecvHandle:
        key = self._key(src, dst, tag)
        r = _RecvHandle()
        with self._lock:
            msgs = self._msgs.get(key)
            if msgs:
                s = msgs.pop(0)
                s.complete(s.payload)
                r.complete(s.payload)
            else:
                self._recvs.setdefault(key, []).append(r)
        return r

    # Blocking conveniences (intercepted like MPI_Recv/MPI_Ssend, Fig. 3).
    def recv(self, *, src: int, dst: int, tag: Any = 0) -> Any:
        return wait(self.irecv(src=src, dst=dst, tag=tag))

    def send(self, payload: Any, *, src: int, dst: int, tag: Any = 0) -> None:
        wait(self.isend(payload, src=src, dst=dst, tag=tag))

    def ssend(self, payload: Any, *, src: int, dst: int, tag: Any = 0) -> None:
        wait(self.isend(payload, src=src, dst=dst, tag=tag, synchronous=True))


# ---------------------------------------------------------------------------
# Ticket pool + polling service (Figs. 3 & 4, bottom halves)
# ---------------------------------------------------------------------------
class _Ticket:
    __slots__ = ("handle", "waiter", "counter", "n_events")

    def __init__(self, handle: AsyncHandle,
                 waiter: Optional[BlockingContext] = None,
                 counter: Optional[EventCounter] = None,
                 n_events: int = 1) -> None:
        self.handle = handle
        self.waiter = waiter      # blocking mode: context to unblock
        self.counter = counter    # non-blocking mode: counter to decrease
        self.n_events = n_events


class _TicketPool:
    """Pending tickets of one runtime, drained by its polling service."""

    def __init__(self, runtime: TaskRuntime) -> None:
        self._lock = threading.Lock()
        self._tickets: List[_Ticket] = []
        runtime.polling.register_polling_service(
            "TAC ticket pool", self.poll, None)

    def add(self, ticket: _Ticket) -> None:
        with self._lock:
            self._tickets.append(ticket)

    def poll(self, _data: Any) -> bool:
        with self._lock:
            snapshot = list(self._tickets)
        completed = [t for t in snapshot if t.handle.test()]
        if completed:
            with self._lock:
                self._tickets = [t for t in self._tickets
                                 if t not in completed]
            for t in completed:
                if t.waiter is not None:
                    unblock_task(t.waiter)            # blocking mode
                if t.counter is not None:
                    decrease_task_event_counter(t.counter, t.n_events)
        return False  # stay registered

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._tickets)


def _pool(runtime: TaskRuntime) -> _TicketPool:
    pool = getattr(runtime, "_tac_pool", None)
    if pool is None:
        with runtime._lock:
            pool = getattr(runtime, "_tac_pool", None)
            if pool is None:
                pool = _TicketPool(runtime)
                runtime._tac_pool = pool  # type: ignore[attr-defined]
    return pool


# ---------------------------------------------------------------------------
# The two interoperability modes
# ---------------------------------------------------------------------------
def wait(handle: AsyncHandle) -> Any:
    """Task-aware blocking wait (§6.1, Fig. 3).

    Inside a task with TASK_MULTIPLE enabled: test; if incomplete, register a
    ticket and *pause the task* — the worker runs other ready tasks and the
    polling service resumes us on completion.  Otherwise: plain blocking wait
    (the PMPI path).
    """
    task = current_task()
    if is_enabled() and task is not None:
        if handle.test():
            return handle.result
        ctx = get_current_blocking_context()
        _pool(task._runtime).add(_Ticket(handle, waiter=ctx))
        block_current_task(ctx)
        return handle.result
    handle.wait()
    return handle.result


def waitall(handles: Sequence[AsyncHandle]) -> List[Any]:
    """Blocking wait on several handles with a single pause/resume cycle."""
    composite = CompositeHandle(handles)
    wait(composite)
    return [h.result for h in handles]


def iwait(handle: AsyncHandle) -> None:
    """TAMPI_Iwait (§6.2, Fig. 4): bind ``handle`` to the task's events.

    Returns immediately.  The calling task's dependencies are released only
    once the task finishes *and* the handle completes.  The buffers produced
    by the operation must not be consumed inside this task after the call —
    consumers declare dependencies instead (Fig. 5).
    """
    task = current_task()
    if is_enabled() and task is not None:
        if handle.test():
            return
        cnt = get_current_event_counter()
        increase_current_task_event_counter(cnt, 1)
        _pool(task._runtime).add(_Ticket(handle, counter=cnt))
        return
    handle.wait()


def iwaitall(handles: Sequence[AsyncHandle]) -> None:
    """TAMPI_Iwaitall (§6.2): bind several handles to the task's events."""
    task = current_task()
    if is_enabled() and task is not None:
        pending = [h for h in handles if not h.test()]
        if not pending:
            return
        cnt = get_current_event_counter()
        increase_current_task_event_counter(cnt, len(pending))
        pool = _pool(task._runtime)
        for h in pending:
            pool.add(_Ticket(h, counter=cnt))
        return
    for h in handles:
        h.wait()
