"""Continuation-based completion notification (poll-free progress).

The polling registry (paper §4.2/§4.5) drives completion by *re-testing*
every in-flight operation each tick — O(in-flight ops) work per poll even
when nothing completed.  Two follow-on papers eliminate that overhead:

* *Callback-based Completion Notification using MPI Continuations*
  (Schuchart et al., EuroMPI'20): attach a callback to one request or a
  set of requests; the library invokes it **once**, at completion time,
  and the continuation request is itself testable/waitable so
  continuations chain.
* *MPI Progress For All* (Zhou et al.): completion work is executed by
  whichever thread is making progress — a dedicated progress thread or
  an otherwise-idle worker — from bounded completion queues, not by the
  operation's poster.

This module is that notification engine for the host runtime:

* :meth:`ContinuationEngine.attach(handles, callback)` registers a
  callback on one handle or a set of handles.  Handles that support
  **push** notification (anything with an ``on_complete`` method —
  :class:`repro.core.tac.EventHandle` and all its subclasses, including
  every CommWorld send/recv handle and :class:`CollectiveHandle`;
  :class:`repro.core.tac.FutureHandle` via
  ``Future.add_done_callback``) notify the engine *at match time*: zero
  tests ever run for them.  Handles without a hook (e.g. JAX
  :class:`~repro.core.tac.ArrayHandle`) fall back to the engine's
  poll list — the only place the engine still tests anything.

* Completion does **not** run the callback inline on the completing
  thread (which may hold communicator locks); the ready record is pushed
  onto a **bounded completion queue** and dispatched either by the
  dedicated poller (the engine registers ONE polling service total — not
  one per operation) or opportunistically by idle workers and at the
  runtime's scheduling points (:class:`repro.core.executor.TaskRuntime`
  drains the queue in ``submit``/``taskwait``).  When the queue is full
  the completing thread dispatches the overflowing record inline — the
  back-pressure discipline of the Continuations paper's bounded queues.

* :meth:`attach` returns a :class:`Continuation` — itself a
  testable/waitable ``AsyncHandle`` that completes once the callback has
  run, so continuations chain (``attach(prev_continuation, next_cb)``)
  and task-aware waits (:func:`repro.core.tac.wait` /
  :func:`~repro.core.tac.iwait`) accept one anywhere they accept an
  operation handle.

The engine keeps honest counters (``stats``): with N in-flight
event-bound operations the continuation path performs **O(completions)**
callback dispatches, where the polling path performs **O(in-flight ×
ticks)** tests — the scaling claim `benchmarks/overlap_bench.py`
measures and `tests/test_continuations.py` asserts.
"""

from __future__ import annotations

import collections
import sys
import threading
import time
import traceback
from typing import Any, Callable, Dict, List, Optional, Sequence

from ..obs import trace as _tr
from ..obs.registry import REGISTRY as _REGISTRY
from ..obs.registry import Counter as _Counter

__all__ = ["PushCompletion", "Continuation", "ContinuationEngine"]


class PushCompletion:
    """Fire-once completion event with **push** callbacks.

    The shared machinery behind every push-capable handle
    (:class:`repro.core.tac.EventHandle` and :class:`Continuation`):
    :meth:`on_complete` registers a callback that fires exactly once, at
    completion time — immediately when already complete.  Subclasses
    complete through :meth:`_complete_once`, whose ``assign`` hook sets
    their result fields *under the same lock* that publishes the event,
    so a racing ``on_complete`` can never observe a set event with
    unassigned results.  Completion is idempotent: the first completion
    wins and fires the callbacks exactly once.
    """

    def __init__(self) -> None:
        self._done = False
        self._cbs: List[Callable] = []
        self._cb_lock = threading.Lock()
        # The OS-level waiter event is built lazily (_wait_event): handle
        # creation is on the critical path of every transfer, and under
        # eager matching / push notification most handles complete
        # without anybody ever blocking on them — a ~µs Event+Condition
        # allocation per handle for nothing, measurable at collective
        # scale (O(n²) handles per allreduce).
        self._waiter: Optional[threading.Event] = None

    def test(self) -> bool:
        return self._done

    def _wait_event(self) -> threading.Event:
        """The blocking-wait event, created on first demand."""
        with self._cb_lock:
            ev = self._waiter
            if ev is None:
                ev = self._waiter = threading.Event()
                if self._done:
                    ev.set()
        return ev

    def on_complete(self, cb: Callable[[Any], None]) -> None:
        """Invoke ``cb(self)`` at completion (immediately if complete)."""
        with self._cb_lock:
            if not self._done:
                self._cbs.append(cb)
                return
        cb(self)

    def _complete_once(self, assign: Callable[[], None]) -> None:
        with self._cb_lock:
            if self._done:
                return
            assign()
            self._done = True
            if self._waiter is not None:
                self._waiter.set()
            cbs, self._cbs = self._cbs, []
        for cb in cbs:
            cb(self)


class Continuation(PushCompletion):
    """Completion handle of one attached callback (testable/waitable).

    Mirrors the :class:`repro.core.tac.AsyncHandle` protocol — ``test``,
    ``wait``, ``result`` — plus the ``on_complete`` push hook, so a
    continuation can be waited on task-aware, bound to an event counter,
    or itself continued (chaining).  ``result`` is the attached handle's
    result (a list, in attachment order, when several handles were
    attached); a raising callback stores its exception in ``error`` and
    ``result`` re-raises it on the consumer.
    """

    def __init__(self) -> None:
        super().__init__()
        self._result: Any = None
        self.error: Optional[BaseException] = None

    def wait(self) -> Any:
        self._wait_event().wait()
        return self.result

    @property
    def result(self) -> Any:
        if self.error is not None:
            raise self.error
        return self._result

    def _fire(self, result: Any, error: Optional[BaseException]) -> None:
        def assign() -> None:
            self._result = result
            self.error = error
        self._complete_once(assign)


class _Pending:
    """One attach(): handles still in flight + the callback to dispatch."""

    __slots__ = ("handles", "callback", "continuation", "_remaining",
                 "_lock", "_ready_at")

    def __init__(self, handles: List[Any], callback: Callable[[], Any],
                 continuation: Continuation) -> None:
        self.handles = handles
        self.callback = callback
        self.continuation = continuation
        self._remaining = len(handles)
        self._lock = threading.Lock()
        self._ready_at: Optional[float] = None  # queue time (tracing only)

    def _arrived(self) -> bool:
        """Count one handle completion; True when the set is complete."""
        with self._lock:
            self._remaining -= 1
            return self._remaining == 0


class ContinuationEngine:
    """Completion queues + dispatch for attached callbacks.

    One engine serves a whole runtime through a single registered polling
    service (:meth:`service`); operations never register services of
    their own.  Push-capable handles cost zero tests; push-less handles
    are polled from the engine's fallback list.  ``stats`` counts:

    * ``attached``     — :meth:`attach` calls,
    * ``completions``  — attachment sets that became ready,
    * ``dispatches``   — callbacks run (== completions, eventually),
    * ``inline_dispatches`` — dispatches run by the completing thread
      because the bounded queue was full (subset of ``dispatches``),
    * ``tests``        — poll-fallback handle tests (0 when every handle
      pushes),
    * ``callback_errors`` — callbacks that raised (error captured on the
      continuation, never on the dispatching thread).

    ``stats`` is a property assembling a fresh dict from **striped
    per-thread counters** (:class:`repro.obs.registry.Counter`): the
    engine lock used to be taken for every single increment — one lock
    round-trip per attach, per completion, and per dispatch on the
    hottest path in the runtime — whereas a striped cell increment is
    lock-free after a thread's first touch.  Totals stay exact
    (``tests/test_continuations.py`` reconciles them against ground
    truth after multi-threaded runs); only inter-counter ordering is
    relaxed, so a mid-flight snapshot may transiently show
    ``dispatches < completions``.

    ``push=False`` is the **legacy polling compatibility mode**: every
    attached handle — push-capable or not — rides the fallback poll list
    and is re-``test``-ed per service tick, reproducing the retired TAC
    ticket pool's O(in-flight × ticks) behaviour on the engine's own
    queue/dispatch path.  ``TaskRuntime(notify="polling")`` builds its
    engine this way, so the continuation engine is the ONE completion
    dispatcher under either backend and only the notification *discipline*
    (push at match time vs re-test per tick) differs.
    """

    def __init__(self, *, queue_capacity: int = 1024,
                 push: bool = True) -> None:
        if queue_capacity < 1:
            raise ValueError(f"queue_capacity must be >= 1, got "
                             f"{queue_capacity}")
        self.queue_capacity = queue_capacity
        self.push = push
        self._lock = threading.Lock()
        self._queue: collections.deque = collections.deque()
        self._polled: List[tuple] = []      # (handle, _Pending) fallbacks
        # Pre-bound striped counters: the emit site is one bound-method
        # call on a lock-free cell, not a dict update under self._lock.
        self._n_attached = _Counter("attached")
        self._n_completions = _Counter("completions")
        self._n_dispatches = _Counter("dispatches")
        self._n_inline = _Counter("inline_dispatches")
        self._n_tests = _Counter("tests")
        self._n_cb_errors = _Counter("callback_errors")

    @property
    def stats(self) -> Dict[str, int]:
        """Exact counter totals, assembled fresh per read."""
        return {"attached": self._n_attached.value,
                "completions": self._n_completions.value,
                "dispatches": self._n_dispatches.value,
                "inline_dispatches": self._n_inline.value,
                "tests": self._n_tests.value,
                "callback_errors": self._n_cb_errors.value}

    # -- the user-facing API ------------------------------------------------
    def attach(self, handles: Any,
               callback: Callable[[], Any]) -> Continuation:
        """Attach ``callback`` to one handle or a set of handles.

        The callback takes no arguments (close over what you need) and
        runs exactly once, after **all** attached handles completed — on
        a dispatching thread (poller, idle worker, or a scheduling
        point), not on the completing one.  Returns the
        :class:`Continuation`, complete once the callback ran.
        """
        if isinstance(handles, (list, tuple)):
            hs = list(handles)
        else:
            hs = [handles]
        if not hs:
            raise ValueError("attach() needs at least one handle")
        rec = _Pending(hs, callback, Continuation())
        self._n_attached.inc()
        if _tr.TRACING:
            _tr.TRACER.instant("continuation", "attach", n_handles=len(hs))
        for h in hs:
            push = getattr(h, "on_complete", None) if self.push else None
            if callable(push):
                # Push path: the handle calls back at match time — this
                # operation is never tested again.
                push(lambda _h, rec=rec: self._arrived(rec))
            else:
                with self._lock:
                    self._polled.append((h, rec))
        return rec.continuation

    # -- completion ---------------------------------------------------------
    def _arrived(self, rec: _Pending) -> None:
        if not rec._arrived():
            return
        self._n_completions.inc()
        inline = False
        if _tr.TRACING:
            rec._ready_at = time.monotonic()
        with self._lock:
            if len(self._queue) >= self.queue_capacity:
                inline = True           # bounded queue full: run it here
            else:
                self._queue.append(rec)
                if _tr.TRACING:
                    _REGISTRY.gauge("continuation.queued").set(
                        len(self._queue))
        if inline:
            self._n_inline.inc()
            self._run(rec)

    def _run(self, rec: _Pending) -> None:
        self._n_dispatches.inc()
        if _tr.TRACING:
            _tr.TRACER.instant("continuation", "dispatch")
            if rec._ready_at is not None:
                # Queue->callback latency: the per-completion dispatch
                # term of simulate.progress_cost, measured.
                _REGISTRY.histogram(
                    "continuation.dispatch_latency_s").observe(
                        time.monotonic() - rec._ready_at)
        try:
            rec.callback()
        except Exception as exc:
            # A raising callback must not kill the dispatching thread —
            # but its continuation may be unreferenced (the wait/iwait
            # wiring discards it), so ALSO report loudly: a swallowed
            # unblock/decrease failure would otherwise hang taskwait
            # with no trace.  KeyboardInterrupt/SystemExit propagate.
            self._n_cb_errors.inc()
            traceback.print_exc()
            print("continuation callback failed (error stored on the "
                  "continuation; see traceback above)", file=sys.stderr)
            rec.continuation._fire(None, exc)
            return
        try:
            # A handle's `result` may itself re-raise (a failed
            # CollectiveHandle, a FutureHandle whose future errored);
            # that is consumer-visible by design — store it quietly, the
            # continuation's reader re-raises it.
            results = [getattr(h, "result", None) for h in rec.handles]
        except Exception as exc:
            self._n_cb_errors.inc()
            rec.continuation._fire(None, exc)
            return
        rec.continuation._fire(
            results[0] if len(results) == 1 else results, None)

    # -- dispatch -----------------------------------------------------------
    def dispatch(self, max_items: Optional[int] = None) -> int:
        """Drain the completion queue; returns #callbacks run.

        Callbacks may themselves complete further handles (a progress
        cascade); those land back on the queue and are drained in the
        same call — total work stays O(completions).
        """
        n = 0
        while max_items is None or n < max_items:
            with self._lock:
                if not self._queue:
                    break
                rec = self._queue.popleft()
            self._run(rec)
            n += 1
        return n

    def service(self, _data: Any = None) -> bool:
        """The ONE polling service: test fallbacks, drain the queue."""
        with self._lock:
            snapshot = list(self._polled)
        if snapshot:
            self._n_tests.inc(len(snapshot))
            done = [item for item in snapshot if item[0].test()]
            if done:
                done_ids = {id(item) for item in done}
                with self._lock:
                    self._polled = [p for p in self._polled
                                    if id(p) not in done_ids]
                for _, rec in done:
                    self._arrived(rec)
        self.dispatch()
        return False                    # stay registered

    # -- introspection ------------------------------------------------------
    @property
    def queued(self) -> int:
        """Ready records awaiting dispatch."""
        with self._lock:
            return len(self._queue)

    @property
    def polled(self) -> int:
        """Push-less handles on the fallback poll list."""
        with self._lock:
            return len(self._polled)
