"""Polling services (paper §4.2, §4.5).

The runtime invokes registered callbacks both *periodically* — a dedicated
management thread processes the list every ``interval`` seconds (Nanos6 uses
1 ms; we default to the same) — and *opportunistically*: worker threads serve
the list before letting their core become idle (§4.5).

A callback returns a truthy value when its purpose has been attained, which
automatically unregisters it; otherwise the runtime keeps calling it.  As in
the paper, callbacks are assumed not to support concurrent execution: each
service carries a lock and concurrent servers skip (rather than wait on) a
service that is already being polled.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional

PollingService = Callable[[Any], bool]


class _Service:
    __slots__ = ("name", "fn", "data", "lock", "done")

    def __init__(self, name: str, fn: PollingService, data: Any) -> None:
        self.name = name
        self.fn = fn
        self.data = data
        self.lock = threading.Lock()
        self.done = False

    def matches(self, name: str, fn: PollingService, data: Any) -> bool:
        return self.name == name and self.fn is fn and self.data is data


class PollingRegistry:
    """Thread-safe registry of polling services with a periodic poller."""

    def __init__(self, interval: float = 0.001) -> None:
        self.interval = interval
        self._lock = threading.Lock()
        self._services: List[_Service] = []
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- the paper's API (§4.2) ------------------------------------------
    def register_polling_service(self, service_name: str,
                                 service_function: PollingService,
                                 service_data: Any = None) -> None:
        with self._lock:
            self._services.append(
                _Service(service_name, service_function, service_data))

    def unregister_polling_service(self, service_name: str,
                                   service_function: PollingService,
                                   service_data: Any = None) -> None:
        """Disable a callback; returns once it is no longer being invoked.

        Removes exactly ONE registration (the oldest still active), so
        register×2 + unregister×1 leaves one live service — matching the
        register/unregister pairing of the paper's API.  The matching
        ``_Service`` is captured under the same registry-lock hold that
        marks it ``done``: a concurrent ``poll_once`` may ``_gc()`` the
        marked service off the list at any point afterwards, so a second
        list snapshot could miss it and return while its callback is
        still running.
        """
        target = None
        with self._lock:
            for s in self._services:
                if not s.done and s.matches(service_name, service_function,
                                            service_data):
                    s.done = True
                    target = s
                    break
        if target is not None:
            # Returning "once the callback has been disabled" (§4.2):
            # grab the captured service's lock so no in-flight invocation
            # remains — the reference outlives any concurrent _gc().
            with target.lock:
                pass
        self._gc()

    # -- invocation --------------------------------------------------------
    def poll_once(self) -> int:
        """Serve the list once (opportunistic path). Returns #invocations."""
        with self._lock:
            snapshot = list(self._services)
        served = 0
        for s in snapshot:
            if s.done:
                continue
            # Callbacks may not support concurrent execution (§4.5): skip if
            # somebody else is already inside this one.
            if not s.lock.acquire(blocking=False):
                continue
            try:
                if s.done:
                    continue
                served += 1
                if s.fn(s.data):
                    s.done = True
            finally:
                s.lock.release()
        self._gc()
        return served

    def _gc(self) -> None:
        with self._lock:
            self._services = [s for s in self._services if not s.done]

    @property
    def num_services(self) -> int:
        with self._lock:
            return len(self._services)

    # -- periodic poller thread (§4.5) ------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name="repro-poller", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    def _run(self) -> None:
        while not self._stop.wait(self.interval):
            self.poll_once()
