"""Checkpointing: mesh-agnostic save/restore + asynchronous saves.

* **Mesh-agnostic format** — every leaf is gathered and written as a full
  array with a JSON manifest of tree paths, so a checkpoint written on one
  mesh restores onto any other (elastic scaling: tested 4×2 → 2×4).  At
  real pod scale the same layout would be written shard-wise per host with
  a resharding read; the manifest format already carries everything needed.

* **Asynchronous saves** (the paper's external-events pattern, §4.3/§6.2):
  ``AsyncCheckpointer.save`` snapshots device arrays and returns
  immediately; the serialisation runs as a task on a host
  :class:`~repro.core.TaskRuntime` whose *dependency release* is what
  gates checkpoint-slot reuse and the final barrier (``wait_all``).
  Training never blocks on I/O.

* **Fault tolerance** — ``latest_step`` + ``restore_checkpoint`` implement
  step-granular restart; ``install_preemption_handler`` flushes a final
  checkpoint on SIGTERM (cluster preemption).
"""

from __future__ import annotations

import json
import os
import re
import signal
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np

from ..core import TaskRuntime, tac

_MANIFEST = "manifest.json"


def _paths_and_leaves(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def _ckpt_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:010d}")


def save_checkpoint(base: str, state: Any, step: int) -> str:
    """Synchronous, mesh-agnostic save."""
    host_state = jax.device_get(state)
    return _write(base, host_state, step)


def _write(base: str, host_state: Any, step: int) -> str:
    d = _ckpt_dir(base, step)
    tmp = d + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    entries = []
    for i, (key, leaf) in enumerate(_paths_and_leaves(host_state)):
        arr = np.asarray(leaf)
        dtype = str(arr.dtype)
        if dtype == "bfloat16":   # numpy can't round-trip ml_dtypes.bfloat16
            arr = arr.view(np.uint16)
        fn = f"{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        entries.append({"path": key, "file": fn,
                        "shape": list(arr.shape), "dtype": dtype})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump({"step": step, "entries": entries}, f)
    if os.path.isdir(d):  # idempotent re-save of the same step
        import shutil
        shutil.rmtree(d)
    os.replace(tmp, d)  # atomic publish
    return d


def latest_step(base: str) -> Optional[int]:
    if not os.path.isdir(base):
        return None
    steps = [int(m.group(1)) for n in os.listdir(base)
             if (m := re.match(r"step_(\d+)$", n))]
    return max(steps) if steps else None


def restore_checkpoint(base: str, abstract_state: Any, shardings: Any = None,
                       step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore onto any mesh: leaves are device_put with the target
    shardings (or host arrays when ``shardings`` is None)."""
    step = step if step is not None else latest_step(base)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {base}")
    d = _ckpt_dir(base, step)
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["entries"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (path, leaf), sh in zip(flat, shard_flat):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        e = by_path[key]
        arr = np.load(os.path.join(d, e["file"]))
        if e["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
    return treedef.unflatten(leaves), manifest["step"]


class AsyncCheckpointer:
    """Non-blocking checkpointing on the host task runtime.

    ``save`` (a) synchronously snapshots the device arrays to host memory
    (fast — device-to-host DMA), then (b) submits the serialisation as a
    task whose completion is observed through the external-events machinery
    (an :class:`~repro.core.tac.EventHandle` fulfilled by the writer).
    Consecutive saves are serialised through an ``inout`` dependency on the
    checkpoint directory; ``wait_all`` is a taskwait.
    """

    def __init__(self, base: str, *, keep: int = 3) -> None:
        self.base = base
        self.keep = keep
        self.runtime = TaskRuntime(num_workers=1)
        self.runtime.start()
        self._lock = threading.Lock()
        self.saved_steps = []

    def save(self, state: Any, step: int) -> tac.EventHandle:
        host_state = jax.device_get(state)   # snapshot now; write later
        done = tac.EventHandle()

        def writer():
            path = _write(self.base, host_state, step)
            with self._lock:
                self.saved_steps.append(step)
            self._gc()
            done.complete(path)

        self.runtime.submit(writer, inout=[("ckpt-dir", self.base)],
                            name=f"ckpt@{step}")
        return done

    def _gc(self) -> None:
        with self._lock:
            if len(self.saved_steps) <= self.keep:
                return
            drop = sorted(self.saved_steps)[:-self.keep]
            self.saved_steps = sorted(self.saved_steps)[-self.keep:]
        for s in drop:
            d = _ckpt_dir(self.base, s)
            if os.path.isdir(d):
                import shutil
                shutil.rmtree(d, ignore_errors=True)

    def wait_all(self) -> None:
        self.runtime.taskwait()

    def close(self) -> None:
        self.wait_all()
        self.runtime.close()


def install_preemption_handler(flush_fn) -> None:
    """Flush a final checkpoint on SIGTERM (cluster preemption signal)."""
    def handler(signum, frame):
        flush_fn()
        raise SystemExit(143)
    signal.signal(signal.SIGTERM, handler)
